"""Mamba2 (SSD — state-space duality) block: chunked scan for train/prefill,
single-step recurrence for decode.  Pure jnp; the intra-chunk hot loop has a
Pallas kernel in repro.kernels.ssd_chunk validated against this reference."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.params import P


def ssm_dims(cfg: ModelConfig):
    d_in = cfg.d_inner
    H = cfg.ssm_heads or d_in // cfg.ssm_head_dim
    Pd = d_in // H
    G, N = cfg.ssm_groups, cfg.ssm_state
    return d_in, H, Pd, G, N


def ssm_spec(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    d_in, H, Pd, G, N = ssm_dims(cfg)
    conv_dim = d_in + 2 * G * N
    return {
        "in_proj": P((D, 2 * d_in + 2 * G * N + H), ("embed", "mlp")),
        "conv_w": P((cfg.d_conv, conv_dim), (None, "mlp"), "fan_in"),
        "conv_b": P((conv_dim,), ("mlp",), "zeros"),
        "A_log": P((H,), (None,), "a_log"),
        "D_skip": P((H,), (None,), "ones"),
        "dt_bias": P((H,), (None,), "zeros"),
        "norm_w": P((d_in,), ("mlp",), "zeros"),
        "out_proj": P((d_in, D), ("mlp", "embed")),
    }


def _causal_conv(x, w, b):
    """x: [B, S, C]; w: [K, C] depthwise causal."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    S = x.shape[1]
    out = sum(pad[:, i:i + S, :] * w[i][None, None, :] for i in range(K))
    return out + b[None, None, :]


def ssd_chunked(x, dt, A, Bc, Cc, chunk: int, state0=None):
    """SSD chunked algorithm.

    x: [B,S,H,P]; dt: [B,S,H]; A: [H] (negative); Bc/Cc: [B,S,G,N].
    Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    B, S, H, Pd = x.shape
    G, N = Bc.shape[2], Bc.shape[3]
    nc = S // chunk
    assert S % chunk == 0, (S, chunk)
    qk = H // G                                    # heads per group

    dA = (dt * A[None, None, :]).astype(jnp.float32)            # [B,S,H]
    xc = x.reshape(B, nc, chunk, H, Pd)
    dtc = dt.reshape(B, nc, chunk, H).astype(jnp.float32)
    dAc = dA.reshape(B, nc, chunk, H)
    Bcc = Bc.reshape(B, nc, chunk, G, N)
    Ccc = Cc.reshape(B, nc, chunk, G, N)
    cum = jnp.cumsum(dAc, axis=2)                               # [B,nc,Q,H]

    # intra-chunk (quadratic within chunk): L[i,j] = exp(cum_i - cum_j) for i>=j
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # [B,nc,Qi,Qj,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.exp(jnp.where(mask[None, None, :, :, None], li, -jnp.inf))
    # scores[i,j,h] = (C_i . B_j) * L * dt_j
    cb = jnp.einsum("bcigh,bcjgh->bcijg", Ccc.astype(jnp.float32),
                    Bcc.astype(jnp.float32))                    # [B,nc,Qi,Qj,G]
    cb = jnp.repeat(cb, qk, axis=-1)                            # -> H
    scores = cb * L * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores,
                         xc.astype(jnp.float32))

    # chunk summaries: state contribution of each chunk
    decay_out = jnp.exp(cum[:, :, -1:, :] - cum)                # [B,nc,Q,H]
    chunk_state = jnp.einsum(
        "bcjhn,bcjhp->bchnp",
        jnp.repeat(Bcc.astype(jnp.float32), qk, axis=3).reshape(B, nc, chunk, H, N),
        xc.astype(jnp.float32) * (dtc * decay_out)[..., None])   # [B,nc,H,N,P]

    # inter-chunk scan over chunk index
    chunk_decay = jnp.exp(cum[:, :, -1, :])                     # [B,nc,H]
    s0 = (jnp.zeros((B, H, N, Pd), jnp.float32) if state0 is None
          else state0.astype(jnp.float32))

    def body(state, xs):
        cs, cd = xs                                             # [B,H,N,P],[B,H]
        out_state = state
        state = state * cd[:, :, None, None] + cs
        return state, out_state

    final, states_in = jax.lax.scan(
        body, s0, (chunk_state.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    states_in = states_in.transpose(1, 0, 2, 3, 4)              # [B,nc,H,N,P]

    decay_in = jnp.exp(cum)                                     # [B,nc,Q,H]
    Ch = jnp.repeat(Ccc.astype(jnp.float32), qk, axis=3).reshape(B, nc, chunk, H, N)
    y_inter = jnp.einsum("bcihn,bchnp->bcihp", Ch * decay_in[..., None], states_in)

    y = (y_intra + y_inter).reshape(B, S, H, Pd)
    return y.astype(x.dtype), final


def ssm_apply(cfg: ModelConfig, p: dict, h, *, cache=None, ctx=None):
    """h: [B,S,D] -> (out, new_cache).  cache={'conv':[B,K-1,Cd],'state':[B,H,N,P]}"""
    B, S, D = h.shape
    d_in, H, Pd, G, N = ssm_dims(cfg)
    cd = h.dtype
    zxbcdt = h @ p["in_proj"].astype(cd)
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:2 * d_in + 2 * G * N]
    dt_raw = zxbcdt[..., 2 * d_in + 2 * G * N:]

    new_cache = None
    if cache is not None and S == 1:                    # decode step
        K = cfg.d_conv
        window = jnp.concatenate([cache["conv"].astype(cd), xBC], axis=1)
        xBC_t = (window * p["conv_w"].astype(cd)[None]).sum(1, keepdims=True) \
            + p["conv_b"].astype(cd)[None, None]
        xBC = jax.nn.silu(xBC_t.astype(jnp.float32)).astype(cd)
        conv_new = window[:, 1:, :]
        x = xBC[..., :d_in].reshape(B, 1, H, Pd)
        Bc = xBC[..., d_in:d_in + G * N].reshape(B, 1, G, N)
        Cc = xBC[..., d_in + G * N:].reshape(B, 1, G, N)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))  # [B,1,H]
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        dA = jnp.exp(dt[..., 0, :] * A[None])                     # [B,H]
        qk = H // G
        Bh = jnp.repeat(Bc[:, 0], qk, axis=1)                     # [B,H,N]
        state = cache["state"].astype(jnp.float32)
        state = state * dA[:, :, None, None] + jnp.einsum(
            "bhn,bhp->bhnp", Bh * dt[:, 0, :, None], x[:, 0].astype(jnp.float32))
        Ch = jnp.repeat(Cc[:, 0], qk, axis=1)
        y = jnp.einsum("bhn,bhnp->bhp", Ch, state)                # [B,H,P]
        y = y + p["D_skip"].astype(jnp.float32)[None, :, None] * x[:, 0].astype(jnp.float32)
        y = y.reshape(B, 1, d_in).astype(cd)
        new_cache = {"conv": conv_new.astype(cache["conv"].dtype),
                     "state": state.astype(cache["state"].dtype)}
    else:                                               # train / prefill
        xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"].astype(cd),
                                       p["conv_b"].astype(cd)).astype(jnp.float32)).astype(cd)
        x = xBC[..., :d_in].reshape(B, S, H, Pd)
        Bc = xBC[..., d_in:d_in + G * N].reshape(B, S, G, N)
        Cc = xBC[..., d_in + G * N:].reshape(B, S, G, N)
        dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                             + p["dt_bias"].astype(jnp.float32))
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        y4, final = ssd_chunked(x, dt, A, Bc, Cc, min(cfg.ssd_chunk, S))
        y = y4 + p["D_skip"].astype(jnp.float32)[None, None, :, None] * x.astype(jnp.float32)
        y = y.reshape(B, S, d_in).astype(cd)
        if cache is not None:                           # prefill: snapshot state
            K = cfg.d_conv
            conv_new = xBC[..., : d_in + 2 * G * N]     # raw pre-conv needed...
            # store last K-1 *pre-activation* inputs: recompute from zxbcdt
            pre = zxbcdt[..., d_in:2 * d_in + 2 * G * N]
            conv_new = pre[:, -(K - 1):, :]
            new_cache = {"conv": conv_new.astype(cache["conv"].dtype),
                         "state": final.astype(cache["state"].dtype)}

    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    g = rmsnorm(g.astype(cd), p["norm_w"], cfg.rms_eps)
    return g @ p["out_proj"].astype(cd), new_cache


def ssm_cache_spec(cfg: ModelConfig, batch: int):
    d_in, H, Pd, G, N = ssm_dims(cfg)
    conv_dim = d_in + 2 * G * N
    return {
        "conv": P((batch, cfg.d_conv - 1, conv_dim), ("batch", None, "mlp"), "zeros"),
        "state": P((batch, H, N, Pd), ("batch", None, "dstate", None), "zeros"),
    }
