"""HALCONE's timestamp/lease rules as pure functions (Algorithms 1-5).

These are the protocol's entire decision surface; the vectorized hierarchy
engine (engine.py), the host-side lease caches (repro.coherence) and the
Pallas lease-probe kernel all call / mirror exactly these rules.

Timestamp conventions (validated against the paper's Fig.5 walkthrough):
  MM read  of a block with TSU entry ``memts``:
      Mwts = memts,     Mrts = memts + RdLease,  memts' = Mrts
      (first read: memts=0 -> lease [0, RdLease] — Fig.5 step 4: [10, 0])
  MM write:
      Mwts = memts + 1, Mrts = memts + WrLease,  memts' = Mrts
      (Fig.5: [Y] memts=7 -> wts=8, rts=12 with WrLease=5;
       [X] memts=10 -> wts=11, rts=15 — the +1 orders the write strictly
       after every read admitted under the previous lease.  Algorithm 3's
       listing elides the +1; the worked example is authoritative.)
  Cache install (read or write response with lease [wts_r, rts_r]):
      Bwts = max(cts, wts_r); Brts = max(Bwts + 1, rts_r)
  cts advances only on writes: cts' = max(cts, Bwts).
  Validity (hit): tag match AND cts <= rts  (no lower bound: HALCONE permits
  "reads in the past" — Fig.5 step 27-29 returns the old [X]).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp

TS_BITS = 16
TS_MAX = (1 << TS_BITS) - 1


class Lease(NamedTuple):
    wts: jnp.ndarray
    rts: jnp.ndarray


def mm_read(memts, rd_lease):
    """TSU action for a read request. Returns (lease, new_memts)."""
    wts = memts
    rts = memts + rd_lease
    return Lease(wts, rts), rts


def mm_write(memts, wr_lease):
    """TSU action for a write request. Returns (lease, new_memts)."""
    wts = memts + 1
    rts = memts + wr_lease
    return Lease(wts, rts), rts


def install(cts, wts_resp, rts_resp):
    """Cache-block timestamp update on a fill/response (Algorithms 1,2,4,5)."""
    bwts = jnp.maximum(cts, wts_resp)
    brts = jnp.maximum(bwts + 1, rts_resp)
    return Lease(bwts, brts)


def cts_after_write(cts, bwts):
    return jnp.maximum(cts, bwts)


def valid(cts, rts):
    """Lease validity: the block may be read while cts <= rts."""
    return cts <= rts


def overflow_reinit(ts):
    """16-bit overflow: re-initialize to 0 instead of flushing (WT means MM
    always holds the data, so the only cost is one extra MM access)."""
    return jnp.where(ts > TS_MAX, jnp.zeros_like(ts), ts)


def order_key(cts, physical_tiebreak):
    """Memory ops are ordered by logical time, ties broken by physical time."""
    return cts * 1_000_000 + physical_tiebreak
