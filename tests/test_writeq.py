"""Direct unit tests for the posted write-through queue.

Two layers share the queue contract (DESIGN.md §11): the host
``WriteQueue`` (a deque draining FIFO past ``max_in_flight``, the
oracle) and the array fabric's fixed ring (``wq_head``/``wq_len`` over
``max_in_flight + 2`` slots, drained by prefix-sum sequencing inside
the op-scan and the batched write pass).  These tests pin the host
object's own semantics — FIFO drain order, fence-over-a-non-empty-queue
clock jump, synchronous degeneration at ``max_in_flight=0`` — and then
the ring against the oracle through enough traffic that the head wraps
the ring many times.
"""
import numpy as np

from repro.coherence.fabric import Op, SharedCache
from repro.coherence.fabric.tsu import FabricConfig, TSUFabric
from repro.coherence.fabric.writeq import WriteQueue

from test_fabric_parity import KEYS, SMALL, assert_equivalent, build_pair


def test_submit_drains_fifo_beyond_max_in_flight():
    """Posted semantics: submit returns immediately; drains happen in
    FIFO order only once more than max_in_flight writes are queued."""
    fab = TSUFabric(FabricConfig(n_shards=1, max_in_flight=2, wr_lease=4))
    q = WriteQueue(fab)
    drained = []
    for i in range(5):
        q.submit(f"k{i}", i, on_complete=lambda g, i=i: drained.append(i))
    assert drained == [0, 1, 2]            # 5 pushes through a 2-deep queue
    assert len(q) == 2
    q.flush()
    assert drained == [0, 1, 2, 3, 4] and len(q) == 0
    assert fab.stats.write_throughs == 5


def test_fence_during_nonempty_queue_drains_then_jumps():
    """The kernel boundary over a NON-EMPTY queue: every queued write
    reaches the TSU first (monotone grant timestamps, FIFO), then the
    barrier returns the jumped clock — no posted write can be lost or
    reordered across a fence."""
    fab = TSUFabric(FabricConfig(n_shards=1, max_in_flight=4, wr_lease=4))
    q = WriteQueue(fab)
    ahead = SharedCache(fab, node_id=0)    # the writer's clock ran ahead
    laggard = SharedCache(fab, node_id=0)
    grants = []
    for i in range(3):
        q.submit(f"k{i}", i, on_complete=grants.append)
    assert len(q) == 3 and not grants      # all still posted
    ahead.cts = 100
    cts = q.fence()
    assert len(q) == 0 and len(grants) == 3
    wtss = [g.wts for g in grants]
    assert wtss == sorted(wtss), "fence drained out of FIFO order"
    assert cts == ahead.cts == 100
    assert laggard.cts == 100, "laggard clock did not jump to the global max"
    assert fab.stats.fences == 1
    assert fab.stats.write_throughs == 3
    # after the jump no reader clock can lag: the fabric's memts for the
    # last-drained key is visible at or below the fence clock
    assert fab.memts("k2") >= grants[-1].rts


def test_max_in_flight_zero_is_synchronous():
    """max_in_flight=0 degenerates to synchronous write-through (the
    legacy adapter behavior): every submit drains before returning."""
    fab = TSUFabric(FabricConfig(n_shards=1, max_in_flight=0))
    q = WriteQueue(fab)
    for i in range(4):
        q.submit(f"k{i}", i)
        assert len(q) == 0
    assert fab.stats.write_throughs == 4


def test_drain_inside_scan_matches_host_oracle():
    """Drains fired INSIDE the array op-scan (pushes past max_in_flight
    mid-trace) match the host queue exactly — per-op results, grant
    order, stats — including the fence that drains the leftovers."""
    host, arr = build_pair(SMALL)          # max_in_flight=2 per node queue
    ops = [Op("write", KEYS[i % 4], f"v{i}", replica=i % 3)
           for i in range(12)]
    ops.append(Op("fence"))
    ops += [Op("read", k, replica=1) for k in KEYS[:4]]
    assert_equivalent(host, arr, ops)
    assert host.stats()["write_throughs"] == 12


def test_ring_wraparound_vs_host_oracle():
    """The array ring (max_in_flight + 2 slots) wraps its head many times
    over a long posted-write workload; every wrap must keep FIFO drain
    order and stay bit-identical to the host deque."""
    host, arr = build_pair(SMALL)          # ring has 4 slots per node
    rng = np.random.default_rng(23)
    pushes = 0
    for c in range(12):
        items = [(KEYS[int(rng.integers(len(KEYS)))], f"w{c}.{i}")
                 for i in range(int(rng.integers(1, 5)))]
        pushes += len(items)
        for b in (host, arr):
            b.write_batch(items, replica=int(c % 2))
        if c % 4 == 3:
            for b in (host, arr):
                b.fence()
    assert pushes > 4 * 4, "workload too small to wrap the 4-slot ring"
    q_len = int(np.asarray(arr._af.wq_len)[0])
    q_head = int(np.asarray(arr._af.wq_head)[0])
    assert 0 <= q_head < 4 and 0 <= q_len <= 2   # head in range, bounded
    assert host.stats() == arr.stats()
    assert list(host.grant_log) == list(arr.grant_log)
    for k in KEYS:
        assert host.memts(k) == arr.memts(k)
