"""Mamba2 SSD intra-chunk kernel (Pallas).

Grid (B, nc, H): each step computes one chunk's quadratic intra-chunk output
and its state summary with everything ([Q,Q] decay/score tiles) resident in
VMEM.  The cheap sequential inter-chunk pass stays in jnp (repro.models.ssm).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, st_ref, cum_ref):
    x = x_ref[0, 0, :, 0].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, 0, :, 0].astype(jnp.float32)        # [Q]
    A = a_ref[0].astype(jnp.float32)                   # scalar
    B = b_ref[0, 0, :, 0].astype(jnp.float32)          # [Q, N]
    C = c_ref[0, 0, :, 0].astype(jnp.float32)          # [Q, N]
    Q = x.shape[0]

    cum = jnp.cumsum(dt * A)                           # [Q]
    li = cum[:, None] - cum[None, :]
    tril = (jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
            >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1))
    L = jnp.exp(jnp.where(tril, li, -jnp.inf))
    scores = (C @ B.T) * L * dt[None, :]
    y_ref[0, 0, :, 0] = (scores @ x).astype(y_ref.dtype)
    decay_out = jnp.exp(cum[-1] - cum)
    st_ref[0, 0, 0] = ((B * (dt * decay_out)[:, None]).T @ x).astype(st_ref.dtype)
    cum_ref[0, 0, :, 0] = cum.astype(cum_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk(x, dt, A, Bc, Cc, *, interpret=True):
    """x: [B,nc,Q,H,P]; dt: [B,nc,Q,H]; A: [H]; Bc/Cc: [B,nc,Q,H,N].

    Returns (y_intra [B,nc,Q,H,P], chunk_state [B,nc,H,N,P], cum [B,nc,Q,H]).
    (B/C already broadcast from groups to heads.)"""
    Bs, nc, Q, H, P = x.shape
    N = Bc.shape[-1]
    grid = (Bs, nc, H)
    y, st, cum = pl.pallas_call(
        _ssd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
            pl.BlockSpec((1,), lambda b, c, h: (h,)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, Q, 1, N), lambda b, c, h: (b, c, 0, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, 1, P), lambda b, c, h: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, 1, N, P), lambda b, c, h: (b, c, h, 0, 0)),
            pl.BlockSpec((1, 1, Q, 1), lambda b, c, h: (b, c, 0, h)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bs, nc, Q, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bs, nc, H, N, P), jnp.float32),
            jax.ShapeDtypeStruct((Bs, nc, Q, H), jnp.float32),
        ],
        interpret=interpret,
    )(x, dt, A, Bc, Cc)
    return y, st, cum
