"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's 512 placeholder
devices to work while smoke tests/benches still see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data","model").
    Multi-pod: 2x16x16 = 512 chips ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (CPU smoke tests / examples): 1 device mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
