"""MetricsRegistry: per-(fabric, scenario) histograms + FabricStats deltas.

The fabric's counter block (``FabricStats`` / ``backend.stats()``) is
cumulative over a backend's lifetime; benchmark scenarios and — per the
ROADMAP's multi-tenant item — per-tenant accounting need *windows*: what
did THIS scenario/batch/tenant add?  The registry layers exactly that on
top without touching the fabric:

  * ``histogram(key, phase)`` — get-or-create a ``LatencyHistogram``
    under an arbitrary hashable key (the convention is a ``(fabric_name,
    scenario)`` tuple; a tenant id slots in as a third element unchanged).
  * ``snapshot(key, stats)`` — capture a counter block (a dict, or any
    object with ``.stats()`` — every ``FabricBackend`` qualifies).
  * ``delta(key, stats)`` — counters accumulated since the last snapshot
    for ``key``; by default advances the snapshot so successive deltas
    tile the timeline without gaps or double counting.

``summary()`` flattens everything into one JSON-able dict, the shape the
benchmark writes next to its throughput rows.
"""
from __future__ import annotations

from typing import Any, Dict, Hashable, Optional, Tuple

from repro.obs.metrics import LatencyHistogram

__all__ = ["MetricsRegistry"]


def _stats_dict(stats: Any) -> Dict[str, int]:
    """Accept a plain counter dict or anything with ``.stats()`` (the
    ``FabricBackend`` surface)."""
    if hasattr(stats, "stats") and callable(stats.stats):
        stats = stats.stats()
    if not isinstance(stats, dict):
        raise TypeError(f"expected a counter dict or a backend, "
                        f"got {type(stats).__name__}")
    return dict(stats)


def _key_str(key: Hashable) -> str:
    if isinstance(key, tuple):
        return "/".join(str(k) for k in key)
    return str(key)


class MetricsRegistry:
    """Windowed metrics over cumulative fabric counters + phase latency."""

    def __init__(self, **hist_kwargs):
        self._hist_kwargs = hist_kwargs
        self._hists: Dict[Tuple[Hashable, str], LatencyHistogram] = {}
        self._snaps: Dict[Hashable, Dict[str, int]] = {}

    # ---------------------------------------------------------- histograms
    def histogram(self, key: Hashable,
                  phase: str = "total") -> LatencyHistogram:
        h = self._hists.get((key, phase))
        if h is None:
            h = self._hists[(key, phase)] = LatencyHistogram(
                **self._hist_kwargs)
        return h

    def observe(self, key: Hashable, phase: str, seconds: float) -> None:
        self.histogram(key, phase).record(seconds)

    # ---------------------------------------------------------- snapshots
    def snapshot(self, key: Hashable, stats: Any) -> Dict[str, int]:
        """Capture the cumulative counter block for ``key``; returns the
        captured copy.  The next ``delta(key, ...)`` is relative to it."""
        snap = _stats_dict(stats)
        self._snaps[key] = snap
        return dict(snap)

    def delta(self, key: Hashable, stats: Any,
              advance: bool = True) -> Dict[str, int]:
        """Counters accumulated since ``key``'s last snapshot.  Counters
        with no prior snapshot diff against zero (a fresh backend's delta
        is its whole block).  ``advance=True`` (default) re-snapshots so
        back-to-back deltas partition the timeline."""
        now = _stats_dict(stats)
        base = self._snaps.get(key, {})
        d = {k: v - base.get(k, 0) for k, v in now.items()}
        if advance:
            self._snaps[key] = now
        return d

    def last_snapshot(self, key: Hashable) -> Optional[Dict[str, int]]:
        snap = self._snaps.get(key)
        return dict(snap) if snap is not None else None

    # ------------------------------------------------------------ export
    def summary(self) -> Dict[str, Dict[str, Any]]:
        """``{key: {"latency": {phase: histogram summary},
        "counters": last snapshot}}`` — one JSON-able block."""
        out: Dict[str, Dict[str, Any]] = {}
        for (key, phase), h in self._hists.items():
            out.setdefault(_key_str(key), {}).setdefault(
                "latency", {})[phase] = h.summary()
        for key, snap in self._snaps.items():
            out.setdefault(_key_str(key), {})["counters"] = dict(snap)
        return out
