"""llama4-maverick-400b-a17b [moe] — 128 routed experts top-1 + 1 shared,
MoE interleaved every other layer; early fusion.
[hf:meta-llama/Llama-4-*] 48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048.
Policy: bf16 optimizer moments (>=200B trick, DESIGN.md)."""
import jax.numpy as jnp

from repro.models.config import ModelConfig, Policy

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=202048, rope_theta=5e5,
    n_experts=128, top_k=1, n_shared_experts=1, d_ff_expert=8192, moe_every=2,
    policy=Policy(param_dtype=jnp.bfloat16, moment_dtype=jnp.bfloat16),
)
