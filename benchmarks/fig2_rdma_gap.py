"""Fig 2: SGEMM local vs remote (RDMA) kernel-time gap — analytic roofline
model of the paper's DGX-1 measurement (local 12.4x..2895x faster).

local  t = max(2N^3/F_gpu, 3N^2*4B / B_hbm)
remote t = latency-bound streaming over the 32 GB/s link with L2-tile reuse:
           blocks = 2N^3/16/tile_reuse; t = blocks * link_lat / MLP
"""
import numpy as np

from benchmarks.common import emit

F_GPU = 14e12            # fp32 FLOP/s (V100-class)
B_HBM = 830e9
B_LINK = 32e9
LINK_LAT = 1.3e-6        # RDMA round trip
MLP = 192                # outstanding remote requests
L2_TILE = 384            # blocked-GEMM tile that fits remote-cached L2


def model(n):
    t_local = max(2 * n**3 / F_GPU, 3 * n * n * 4 / B_HBM)
    blocks = 2 * n**3 / 16 / L2_TILE
    t_remote = max(t_local, blocks * LINK_LAT / MLP,
                   2 * n**3 / L2_TILE * 4 / B_LINK)
    return t_local, t_remote


def main(force=False):
    for n in (512, 2048, 8192, 32768):
        tl, tr = model(n)
        emit(f"fig2/sgemm_n{n}", tl * 1e6, f"remote_slowdown={tr/tl:.1f}x")


if __name__ == "__main__":
    main()
