"""Per-architecture smoke tests: reduced config, one forward/train step on CPU,
asserting output shapes and no NaNs.  Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs as cfgs
from repro.models import (abstract_model, applicable_shapes, decode_step,
                          init_cache, init_model, loss_fn, prefill)
from repro.models.params import count_params

ARCH_IDS = list(cfgs.ARCHS)


def make_batch(cfg, key, B=2, S=32):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.frontend == "audio":
        batch = {"frames": jax.random.normal(ks[1], (B, S, cfg.d_frontend)),
                 "labels": jax.random.randint(ks[0], (B, S), 0, cfg.vocab)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(
            ks[2], (B, cfg.n_patch_tokens, cfg.d_model), jnp.float32) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = cfgs.SMOKE[arch]
    key = jax.random.PRNGKey(0)
    params = init_model(cfg, key)
    batch = make_batch(cfg, key)

    (loss, metrics), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, batch), has_aux=True)(params)
    assert np.isfinite(float(loss)), f"{arch}: loss NaN/Inf"
    assert float(loss) > 0
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: grad NaN/Inf"
    assert float(gnorm) > 0, f"{arch}: zero grads"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_smoke(arch):
    cfg = cfgs.SMOKE[arch]
    if not cfg.causal:
        pytest.skip("encoder-only: no decode step")
    B, S = 2, 16
    key = jax.random.PRNGKey(1)
    params = init_model(cfg, key)
    cache = init_cache(cfg, B, max_len=S + 8)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab)
    patches = (jax.random.normal(key, (B, cfg.n_patch_tokens, cfg.d_model))
               * 0.02 if cfg.frontend == "vision" else None)
    nxt, cache = prefill(cfg, params, tokens, cache, patches=patches)
    assert nxt.shape == (B,)
    for step in range(3):
        nxt, cache = decode_step(cfg, params, cache, nxt[:, None],
                                 jnp.int32(S + step))
        assert nxt.shape == (B,)
        assert np.all(np.asarray(nxt) >= 0) and np.all(np.asarray(nxt) < cfg.vocab)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_abstract_instantiation_full_config(arch):
    """Full configs instantiate abstractly (no allocation) with sane counts."""
    cfg = cfgs.ARCHS[arch]
    tree = abstract_model(cfg)
    n = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))
    assert n > 1e8, f"{arch}: suspiciously few params {n}"
    shapes = applicable_shapes(cfg)
    assert shapes, arch


def test_param_counts_match_public_models():
    """Loose sanity bands against the public configs' reported sizes."""
    expect = {
        "mamba2-130m": (0.1e9, 0.2e9),
        "qwen1.5-110b": (100e9, 120e9),
        "smollm-360m": (0.3e9, 0.45e9),
        "qwen2.5-14b": (13e9, 16e9),
        "gemma3-4b": (3e9, 5.5e9),
        "llava-next-34b": (30e9, 38e9),
        "llama4-maverick-400b-a17b": (370e9, 430e9),
        "deepseek-v2-236b": (210e9, 250e9),
        "zamba2-1.2b": (0.9e9, 1.5e9),
        "hubert-xlarge": (0.9e9, 1.3e9),
    }
    from repro.models import model_spec
    for arch, (lo, hi) in expect.items():
        n = count_params(model_spec(cfgs.ARCHS[arch]))
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B params not in [{lo/1e9},{hi/1e9}]B"


def test_mla_absorbed_decode_matches_naive():
    """DeepSeek weight-absorption decode == naive per-head K/V decode."""
    import dataclasses
    cfg_a = cfgs.SMOKE["deepseek-v2-236b"]
    cfg_n = dataclasses.replace(cfg_a, mla_absorb=False)
    B, S = 2, 16
    key = jax.random.PRNGKey(7)
    params = init_model(cfg_a, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg_a.vocab)
    outs = []
    for cfg in (cfg_a, cfg_n):
        cache = init_cache(cfg, B, max_len=S + 4)
        nxt, cache = prefill(cfg, params, tokens, cache)
        ids = [np.asarray(nxt)]
        for t in range(3):
            nxt, cache = decode_step(cfg, params, cache, nxt[:, None],
                                     jnp.int32(S + t))
            ids.append(np.asarray(nxt))
        outs.append(np.stack(ids))
    np.testing.assert_array_equal(outs[0], outs[1])
