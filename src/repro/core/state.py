"""Array-native coherence state layer: the ONE implementation of the
hierarchy transition rules.

HALCONE's pitch is that every coherence decision is local arithmetic over
``[wts, rts]`` leases — so the whole hierarchy (L1/replica tier, L2/shared
tier, TSU) is representable as a handful of int32 arrays plus pure, batched
transition functions.  This module holds exactly that:

  * ``TierState``  — one set-associative lease tier ([N, S, W+1] arrays with
    a trailing trash way for masked scatters) — the simulator's L1 and L2
    AND the fabric's replica/shared client tiers.
  * ``TSUState``   — the timestamp-storage-unit rows (tag + 16-bit memts) —
    the simulator's per-HBM-stack TSU AND the fabric's per-shard MM+TSU
    table (shaped ``[n_shards, 1, capacity+1]``, i.e. one fully-associative
    set per shard).
  * transition functions — probe / victim selection / the TSU grant
    (Algorithm 3 + 16-bit overflow reinit) / the fused tier probe+install
    (Algorithms 1, 2, 4, 5 via ``kernels.lease_probe``) / the TSU commit.
  * packed buffers + batched rules — each tier's arrays as ONE contiguous
    buffer (``pack_tier``/``pack_tsu``), the grouped-by-owner shard
    exchange (``owner_gather``/``owner_take``), and the whole-batch TSU
    transition (``tsu_lease_batch``/``tsu_commit_batch``) that the
    batched grant pipeline (DESIGN.md §9) is built from.  The per-op
    rules above remain the oracle these must match bit-for-bit.

Both consumers import from here and re-derive NOTHING:

  * ``core/engine.py`` — the timing simulator: one ``round_step`` scan,
    requests batched over all CUs.
  * ``coherence/fabric/arrays.py`` — the production fabric: one op-scan,
    requests batched per serving/training batch.

All timestamp arithmetic is ``repro.core.protocol``; all fused probe+install
math is ``kernels.lease_probe`` (compiled Pallas on TPU/GPU, interpret
fallback on CPU — bit-identical, see DESIGN.md §5).  No other module may
implement these rules (DESIGN.md §7 backend-parity contract).
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import protocol
from repro.kernels.lease_probe import lease_probe
from repro.kernels.tier_pass import write_grant

INVALID = jnp.int32(-1)

# ------------------------------------------------------- link traffic (Fig 10)
# Every hierarchy hop moves one data block; directory invalidations (HMG)
# are control-sized messages.  These two constants + ``link_bytes`` are the
# ONE definition of the paper's Fig-10 per-link traffic accounting: the
# timing simulator (engine.COUNTERS) and the production fabric
# (FabricStats) both report bytes through this helper, so a simulated
# trace and a served trace decompose identically.
BLOCK_BYTES = 64        # one cache block / KV line on any data link
CTRL_BYTES = 8          # one invalidation / control message (HMG only)


def link_bytes(l1_l2_msgs, l2_mm_msgs, inter_gpu_blocks, inval_msgs=0):
    """Per-link byte counters (L1<->L2, L2<->MM, inter-GPU).

    Works on python ints and on traced arrays alike.  HALCONE's headline
    (Fig. 10): ``inval_msgs`` is 0 by construction, so its inter-GPU bytes
    are pure data; HMG pays ``CTRL_BYTES`` per invalidation on the same
    low-bandwidth links.
    """
    return (l1_l2_msgs * BLOCK_BYTES,
            l2_mm_msgs * BLOCK_BYTES,
            inter_gpu_blocks * BLOCK_BYTES + inval_msgs * CTRL_BYTES)


# ------------------------------------------------------ per-op result block
# The packed per-op result record shared by the fabric's batched miss pass
# (coherence/fabric/pipeline.py, [7, M]) and the simulator's round step
# (core/engine.py, [7, NC] per round): field order is the layout contract
# for the stacked int32 buffer both emit, so serving traces and figure
# sweeps decode per-op results identically (ROADMAP miss-pass telemetry).
#   found    1 iff the op produced/committed a value
#   version  data version returned (reads) or committed (writes); -1 none
#   gseq     payload write-sequence handle (fabric only; simulator: -1)
#   level    read service level 0=L1 1=L2 2=peer/home 3=MM; -1 non-read
#   wts/rts  the lease installed at the top tier (0 when none)
#   mm_used  1 iff the op reached the MM/TSU authority
RES_FIELDS = ("found", "version", "gseq", "level", "wts", "rts", "mm_used")


# ----------------------------------------------------------------- states
class TierState(NamedTuple):
    """One set-associative lease tier.

    Arrays are ``[N, S, W+1]`` (N caches x S sets x W ways + 1 trash way
    used as the target of masked scatters; a real tag never lands there).
    ``cts`` is the per-cache logical clock ``[N]``.
    """

    tag: jnp.ndarray     # int32, INVALID = empty
    wts: jnp.ndarray
    rts: jnp.ndarray
    ver: jnp.ndarray     # data version carried by the line
    lru: jnp.ndarray     # victim score (higher = more recently used)
    cts: jnp.ndarray     # [N] logical clocks

    @property
    def n_ways(self) -> int:
        return self.tag.shape[-1] - 1


class TSUState(NamedTuple):
    """Timestamp-storage-unit rows: ``[H, S, W+1]`` tag + memts."""

    tag: jnp.ndarray
    memts: jnp.ndarray

    @property
    def n_ways(self) -> int:
        return self.tag.shape[-1] - 1


def init_tier(n: int, sets: int, ways: int) -> TierState:
    shp = (n, sets, ways + 1)
    z = lambda: jnp.zeros(shp, jnp.int32)
    return TierState(tag=jnp.full(shp, INVALID), wts=z(), rts=z(), ver=z(),
                     lru=z(), cts=jnp.zeros((n,), jnp.int32))


def init_tsu(h: int, sets: int, ways: int) -> TSUState:
    shp = (h, sets, ways + 1)
    return TSUState(tag=jnp.full(shp, INVALID),
                    memts=jnp.zeros(shp, jnp.int32))


# ----------------------------------------------------------------- probes
def probe(tag_arr, idx, set_idx, addr):
    """Tag-only probe over the live ways of each request's set.

    tag_arr: [N, S, W+1]; idx/set_idx/addr: [n].  Returns (tag_hit, way) —
    ``way`` is the FIRST matching way (argmax over the match mask), the
    convention every consumer and the Pallas kernel share.
    """
    rows = tag_arr[idx, set_idx][..., :-1]          # [n, W]
    eq = rows == addr[..., None]
    return eq.any(-1), jnp.argmax(eq, -1)


def victim(tag_arr, score_arr, idx, set_idx):
    """Victim way: invalid ways first, else the minimum score; ties break to
    the FIRST such way (argmin), matching the host stores' strict-< scan."""
    rows_t = tag_arr[idx, set_idx][..., :-1]
    rows_s = score_arr[idx, set_idx][..., :-1]
    score = jnp.where(rows_t == INVALID, jnp.int32(-2 ** 30), rows_s)
    return jnp.argmin(score, -1)


def victim_lex(tag_arr, primary, secondary, idx, set_idx):
    """Lexicographic victim: invalid first, else min primary, ties broken by
    min secondary (the fabric TSU's dict-order rule: among equal-``memts``
    entries the earliest-allocated is evicted)."""
    rows_t = tag_arr[idx, set_idx][..., :-1]
    rows_p = primary[idx, set_idx][..., :-1]
    rows_s = secondary[idx, set_idx][..., :-1]
    invalid = rows_t == INVALID
    p = jnp.where(invalid, jnp.int32(-2 ** 30), rows_p)
    pmin = jnp.min(p, -1, keepdims=True)
    s = jnp.where(p == pmin, rows_s, jnp.int32(2 ** 30))
    return jnp.argmin(s, -1)


# ------------------------------------------------------------- TSU grant
class TSUGrant(NamedTuple):
    wts: jnp.ndarray        # the [wts, rts] lease the TSU grants
    rts: jnp.ndarray
    new_memts: jnp.ndarray  # the clock the entry holds afterwards
    overflow: jnp.ndarray   # bool: the 16-bit reinit fired


def tsu_lease(memts, is_write, rd_lease, wr_lease) -> TSUGrant:
    """The TSU decision (Algorithm 3, Fig. 5 conventions) for a batch of
    requests against their entries' current clocks, including the 16-bit
    overflow reinit (DESIGN.md §3a): a grant that would push ``memts`` past
    ``protocol.TS_MAX`` restarts the entry at 0 and is re-served as a first
    read — wts=0, rts=lease, memts'=rts (write-through keeps MM correct).

    memts: [n] current entry clocks (0 for fresh/missing entries);
    is_write: [n] bool; rd_lease/wr_lease: scalars or [n].
    """
    r_lease, r_memts = protocol.mm_read(memts, rd_lease)
    w_lease, w_memts = protocol.mm_write(memts, wr_lease)
    wts = jnp.where(is_write, w_lease.wts, r_lease.wts)
    rts = jnp.where(is_write, w_lease.rts, r_lease.rts)
    new_memts = jnp.where(is_write, w_memts, r_memts)
    ovf = new_memts > protocol.TS_MAX
    wts = jnp.where(ovf, 0, wts)
    rts = jnp.where(ovf, jnp.where(is_write, wr_lease, rd_lease), rts)
    new_memts = jnp.where(ovf, rts, new_memts)
    return TSUGrant(wts, rts, new_memts, ovf)


def tsu_commit_scatter(tsu: TSUState, idx, set_idx, way, addr, new_memts,
                       active, tag_hit) -> TSUState:
    """The simulator's TSU state update: same-round requests to one slot are
    resolved by scatter-max (same-tick semantics, paper §3.2 — the largest
    extension wins; on an eviction-install the largest tag keeps the slot).
    Inactive requests are routed to the trash way.
    """
    tw = jnp.where(active, way, tsu.n_ways)
    tag = tsu.tag.at[idx, set_idx, tw].max(
        jnp.where(active, addr, INVALID))
    cleared = jnp.where(active & ~tag_hit, 0, tsu.memts[idx, set_idx, tw])
    memts = tsu.memts.at[idx, set_idx, tw].set(
        jnp.where(active, jnp.maximum(cleared, 0), cleared))
    memts = memts.at[idx, set_idx, tw].max(jnp.where(active, new_memts, 0))
    return TSUState(tag=tag, memts=memts)


def tsu_commit_exact(tsu: TSUState, idx, set_idx, way, addr, new_memts,
                     active) -> TSUState:
    """The fabric's TSU state update: one op at a time, so the slot is
    written exactly (the host dict's replace semantics — no scatter-max
    races to resolve).  Inactive ops are routed to the trash way."""
    tw = jnp.where(active, way, tsu.n_ways)
    return TSUState(
        tag=tsu.tag.at[idx, set_idx, tw].set(
            jnp.where(active, addr, tsu.tag[idx, set_idx, tw])),
        memts=tsu.memts.at[idx, set_idx, tw].set(
            jnp.where(active, new_memts, tsu.memts[idx, set_idx, tw])))


# -------------------------------------------------- tier probe + install
def install_lease(cts, wts_resp, rts_resp):
    """Install math alone (Algorithms 1/2 + writer clock), for fills whose
    way is already known: returns (new_wts, new_rts, new_cts).  The same
    arithmetic ``tier_probe`` fuses with the probe via the Pallas kernel."""
    lease = protocol.install(cts, wts_resp, rts_resp)
    return lease.wts, lease.rts, protocol.cts_after_write(cts, lease.wts)


def tier_probe(tier: TierState, idx, set_idx, addr, mwts, mrts):
    """Fused probe + install math for one tier — the per-request coherence
    action, served by the Pallas lease-probe kernel.

    Gathers each request's set row from ``tier`` and runs the kernel:
    tag compare (first-match way), lease validity (``protocol.valid``),
    Algorithm 1/2 install (``protocol.install``) of the response lease
    ``(mwts, mrts)`` arriving from the level below, and the writer clock
    advance (``protocol.cts_after_write``).

    Returns (tag_hit, hit, way, row_rts, new_wts, new_rts, new_cts); see
    ``kernels.lease_probe`` for the exact contract.  Callers that only need
    the probe half may pass zeros for (mwts, mrts) and ignore the install
    outputs; callers that only need the install half ignore the hit outputs.
    """
    return lease_probe(tier.tag[idx, set_idx][..., :-1],
                       tier.rts[idx, set_idx][..., :-1],
                       tier.cts[idx], addr, mwts, mrts)


# ------------------------------------------------- packed contiguous buffers
# The batched grant pipeline (coherence/fabric, DESIGN.md §9) moves tier /
# TSU state as ONE contiguous buffer per tier: packing turns the per-batch
# cross-shard exchange into a single collective and the per-request row
# access into a single gather.  Field order is part of the layout contract.
TIER_FIELDS = ("tag", "wts", "rts", "ver", "lru")
TSU_FIELDS = ("tag", "memts", "ver", "gseq", "seq", "nseq")


def pack_tier(tier: TierState) -> jnp.ndarray:
    """Per-tier arrays as ONE contiguous ``[5, N, S, W+1]`` buffer
    (``TIER_FIELDS`` order; ``cts`` stays separate — it is per-cache, not
    per-line)."""
    return jnp.stack([tier.tag, tier.wts, tier.rts, tier.ver, tier.lru])


def unpack_tier(buf: jnp.ndarray, cts: jnp.ndarray) -> TierState:
    return TierState(tag=buf[0], wts=buf[1], rts=buf[2], ver=buf[3],
                     lru=buf[4], cts=cts)


def pack_tsu(tsu: TSUState, ver, gseq, seq, nseq) -> jnp.ndarray:
    """The TSU tier plus its per-shard sequencers as ONE contiguous
    ``[6, H, S, W+1]`` buffer (``TSU_FIELDS`` order) — the payload of the
    batched pipeline's one-collective-per-batch shard exchange.  ``nseq``
    is ``[H]``; it rides in field 5 at ``[:, 0, 0]`` (the rest of that
    plane is padding, never read back)."""
    f5 = jnp.zeros_like(tsu.tag).at[:, 0, 0].set(nseq)
    return jnp.stack([tsu.tag, tsu.memts, ver, gseq, seq, f5])


def unpack_tsu(buf: jnp.ndarray) -> Tuple:
    """Inverse of ``pack_tsu``: (TSUState, ver, gseq, seq, nseq)."""
    return (TSUState(tag=buf[0], memts=buf[1]), buf[2], buf[3], buf[4],
            buf[5][:, 0, 0])


def owner_gather(packed: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Grouped-by-owner gather: assemble the full shard-major buffer from
    every device's contiguous owned rows — ONE ``all_gather`` over the
    mesh axis, the batched pipeline's single per-batch collective.

    packed: ``[F, H_local, ...]`` (this device's rows).  Returns
    ``[F, H_local * D, ...]`` with device ``d``'s rows at
    ``[d*H_local, (d+1)*H_local)`` — the same shard-major placement
    ``NamedSharding`` lays out."""
    full = jax.lax.all_gather(packed, axis_name)        # [D, F, Hl, ...]
    full = jnp.moveaxis(full, 0, 1)                     # [F, D, Hl, ...]
    return full.reshape((full.shape[0],
                         full.shape[1] * full.shape[2]) + full.shape[3:])


def owner_take(packed_full: jnp.ndarray, me, rows: int) -> jnp.ndarray:
    """Grouped-by-owner scatter (the no-communication half): slice this
    device's contiguous ``rows`` shard rows back out of the full buffer."""
    return jax.lax.dynamic_slice_in_dim(packed_full, me * rows, rows, axis=1)


def tsu_commit_batch(tsu: TSUState, idx, set_idx, way, addr, new_memts,
                     active) -> TSUState:
    """Batched exact TSU commit: one scatter for a whole batch of grants.

    Same slot semantics as ``tsu_commit_exact`` (the host dict's replace),
    vectorized — the caller must guarantee that no two ACTIVE requests in
    the batch target the same ``(idx, set_idx, way)`` slot (one request
    per key per call; distinct keys always occupy distinct slots).
    Inactive requests are routed to the trash way and write back the
    slot's original values."""
    return tsu_commit_exact(tsu, idx, set_idx, way, addr, new_memts, active)


def tsu_commit_write_batch(tsu: TSUState, ver_arr, gseq_arr, seq_arr, nseq,
                           gseq0, shard, key, wr_eff, rd_lease, active):
    """The batched write-side TSU transition: ONE probe + allocation +
    grant + commit for a whole batch of write-throughs (the ``mm_write``
    half of the batched write pass, DESIGN.md §11 — mirrors
    ``tsu_lease_batch`` the way writes mirror reads).

    Per request: probe the shard's fully-associative set; on a miss,
    allocate — evicting the min-``(memts, alloc_seq)`` entry when the
    shard is full (``victim_lex``, the host ``TSUShard`` dict-order
    rule); grant via Algorithm 3 as a write (+ the 16-bit overflow
    reinit) against the entry's current clock; bump the version
    (``ver+1`` in place, 1 on a fresh allocation) and stamp the grant
    with a globally unique write-sequence id ``gseq0 + rank`` — all
    vectorized, one scatter per side array.

    Requires DISTINCT active keys AND at most one active write per
    shard per call: a second allocation in one shard is sequentially
    coupled to the first through the victim choice and the per-shard
    allocation sequencer, so the write pass's conflict rounds
    (``pipeline.write_schedule``) never co-schedule two TSU writes to
    one shard.

    shard/key/wr_eff: [n] (``wr_eff`` is the already-resolved write
    lease — the op's override or the config default); active: [n] bool.
    Returns ``(wts, rts, ver, gs, evict, overflow, new_tsu, new_ver,
    new_gseq, new_seq, new_nseq, new_gseq_next)``: wts/rts/ver/gs are
    the grant fields (gs = -1 on inactive lanes), ``evict`` flags
    full-set victim evictions, ``overflow`` flags grants that
    re-initialized the entry."""
    i32 = jnp.int32
    b2i = lambda b: b.astype(i32)
    zset = jnp.zeros_like(shard)
    cap = tsu.n_ways
    # fused probe + lex victim + mm_write grant (ONE Pallas grid pass —
    # kernels.tier_pass.write_grant, the write-side twin of the miss
    # round's fused kernel; same victim_lex/tsu_lease math, bit-exact)
    th, w0, full, g_wts, g_rts, g_memts, g_ovf = write_grant(
        tsu.tag[shard, zset][..., :-1], tsu.memts[shard, zset][..., :-1],
        seq_arr[shard, zset][..., :-1], key,
        jnp.broadcast_to(jnp.asarray(wr_eff, i32), key.shape))
    gr = TSUGrant(g_wts, g_rts, g_memts, g_ovf)
    evict = active & ~th & full
    ver = jnp.where(th, ver_arr[shard, zset, w0] + 1, 1)
    seqv = jnp.where(th, seq_arr[shard, zset, w0], nseq[shard])
    rank = jnp.cumsum(b2i(active)) - b2i(active)       # exclusive gseq rank
    gs = jnp.where(active, gseq0 + rank, -1)
    new_tsu = tsu_commit_batch(tsu, shard, zset, w0, key, gr.new_memts,
                               active)
    w = jnp.where(active, w0, cap)                     # trash-way routing

    def pt(a, v):
        return a.at[shard, zset, w].set(
            jnp.where(active, v, a[shard, zset, w]))

    new_nseq = nseq.at[jnp.where(active, shard, 0)].add(
        b2i(active & ~th))
    return (gr.wts, gr.rts, ver, gs, evict, active & gr.overflow, new_tsu,
            pt(ver_arr, ver), pt(gseq_arr, gs), pt(seq_arr, seqv),
            new_nseq, gseq0 + jnp.sum(b2i(active)))


def tsu_lease_batch(tsu: TSUState, ver_arr, gseq_arr, shard, key,
                    rd_lease, wr_lease, active):
    """The batched read-side TSU transition: ONE probe + grant + commit for
    a whole batch of requests (the ``mm_read`` half of the batched grant
    pipeline, DESIGN.md §9).

    Per request: probe the shard's fully-associative set, grant via
    Algorithm 3 (+ the 16-bit overflow reinit) against the entry's current
    clock, and commit the extended ``memts`` exactly — all vectorized.
    Requires DISTINCT active keys (one request per key per call; the
    pipeline's conflict-round grouping guarantees it), because the commit
    is a one-shot batched scatter.

    shard/key: [n]; active: [n] bool (inactive requests touch nothing).
    Returns (found, wts, rts, ver, gseq, overflow, new_tsu): ``found`` is
    active AND the entry exists; ver/gseq are -1 when not found;
    ``overflow`` flags found grants that re-initialized the entry."""
    zset = jnp.zeros_like(shard)
    th, way = probe(tsu.tag, shard, zset, key)
    found = active & th
    memts = jnp.where(th, tsu.memts[shard, zset, way], 0)
    gr = tsu_lease(memts, jnp.zeros(key.shape, bool), rd_lease, wr_lease)
    new = tsu_commit_batch(tsu, shard, zset, way, key, gr.new_memts, found)
    ver = jnp.where(found, ver_arr[shard, zset, way], -1)
    gs = jnp.where(found, gseq_arr[shard, zset, way], -1)
    return found, gr.wts, gr.rts, ver, gs, found & gr.overflow, new
