"""Fig 9: Xtreme stress suite — SM-WT-C-HALCONE vs SM-WT-NC across vector
sizes.  Paper: worst-case degradation 14.3% (X1) / 12.1% (X2) / 16.8% (X3)
at 192 KB vectors, shrinking toward ~0.6% as capacity misses take over.

All 9 (variant, size) traces are NOP-padded into one [B, NC, R] batch and
both configs swept in one jit (DESIGN.md §5)."""
import numpy as np

from benchmarks import common
from benchmarks.common import cached, emit
from repro.core.sysconfig import sm_wt_halcone, sm_wt_nc
from repro.core.traces import XtremeSpec, xtreme

# (blocks_per_slice, reps, label) — 128 CUs => vector = slice*128*64B,
# so 24 blocks/slice = the paper's smallest 192KB vectors
SIZES = [(24, 10, "192KB"), (96, 4, "768KB"), (384, 2, "3MB")]
SYS = dict(n_gpus=4, cus_per_gpu=32)


def run_all(force=False):
    def compute():
        base = sm_wt_halcone(**SYS)
        named = {}
        for variant in (1, 2, 3):
            for nb, reps, label in SIZES:
                named[f"xtreme{variant}/{label}"] = \
                    xtreme(base, XtremeSpec(variant, nb, reps))
        out = common.sweep([("SM-WT-C-HALCONE", sm_wt_halcone(**SYS)),
                            ("SM-WT-NC", sm_wt_nc(**SYS))], named,
                           measure_sequential=False)
        hc, nc = out["cycles"]
        coh = out["counters"]["coh_miss_l1"][0]
        res = {}
        for bi, cell in enumerate(out["benchmarks"]):
            variant, label = cell.split("/")
            res.setdefault(variant, {})[label] = {
                "slowdown_pct": (hc[bi] / nc[bi] - 1) * 100,
                "coh_miss_l1": coh[bi],
            }
        res["wall"] = out["wall"]
        return res

    return cached("fig9_xtreme", compute, force, script=__file__)


def main(force=False):
    data = run_all(force)
    worst = 0.0
    for variant, sizes in data.items():
        if variant == "wall":
            continue
        for label, rec in sizes.items():
            emit(f"fig9/{variant}/{label}", 0.0,
                 f"halcone_slowdown={rec['slowdown_pct']:.1f}%")
            worst = max(worst, rec["slowdown_pct"])
    emit("fig9/worst_case", 0.0, f"slowdown={worst:.1f}% (paper: 16.8%)")
    return data


if __name__ == "__main__":
    main()
