"""Fabric observability: span tracing, latency metrics, static cost probes.

Three parts (DESIGN.md §10), all layered OVER the fabric — nothing in this
package participates in a coherence decision, and with tracing disabled
(the default) the instrumentation costs <1% on the batched serving path
(the paper's own overhead bar, pinned by tests/test_obs.py):

  * ``trace``    — a low-overhead host-side span tracer emitting
    Chrome-trace/Perfetto-compatible JSON; spans wrap every fabric batch
    lifecycle phase (pack → exchange → scan → miss pass → decode →
    donate) plus the jit-dispatch vs device-execute split via
    ``block_until_ready`` fencing.
  * ``metrics`` / ``registry`` — log-bucketed latency histograms with
    exact p50/p95/p99 summaries and a ``MetricsRegistry`` keyed by
    (fabric, scenario) with snapshot/delta semantics over ``FabricStats``
    counter blocks.
  * ``xprof``    — static cost probes: a jaxpr walker counting collectives
    (the generalization of ``pipeline.collective_counts``) plus compiled
    cost analysis (FLOPs, bytes accessed) per fabric function.
"""
from repro.obs.metrics import LatencyHistogram
from repro.obs.registry import MetricsRegistry
from repro.obs.trace import Tracer, disable, enable, get_tracer, set_tracer
from repro.obs.xprof import cost_probe, jaxpr_collectives

__all__ = [
    "LatencyHistogram", "MetricsRegistry", "Tracer",
    "enable", "disable", "get_tracer", "set_tracer",
    "cost_probe", "jaxpr_collectives",
]
