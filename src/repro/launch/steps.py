"""Jittable step functions (train / prefill / decode) + their shardings and
abstract input specs for every (arch x shape) dry-run cell."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.models import config as mcfg
from repro.models import model as M
from repro.models.params import abstract, shardings
from repro.optim import adamw
from repro.sharding import ShardCtx, named_sharding


# ------------------------------------------------------------------ specs
def batch_abstract(cfg: mcfg.ModelConfig, cell: mcfg.ShapeCell):
    B, S = cell.global_batch, cell.seq_len
    if cfg.frontend == "audio":
        return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_frontend),
                                               jnp.bfloat16),
                "labels": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    batch = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.frontend == "vision":
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.n_patch_tokens, cfg.d_model), jnp.bfloat16)
    return batch


def batch_shardings(cfg: mcfg.ModelConfig, cell: mcfg.ShapeCell, mesh):
    ab = batch_abstract(cfg, cell)
    ax = {"tokens": ("batch", None), "labels": ("batch", None),
          "frames": ("batch", None, None), "patches": ("batch", None, None)}
    return {k: named_sharding(mesh, v.shape, ax[k]) for k, v in ab.items()}


def _replicated(mesh):
    return NamedSharding(mesh, PartitionSpec())


# ------------------------------------------------------------------ train
def make_train_step(cfg: mcfg.ModelConfig, mesh,
                    opt: adamw.AdamWConfig = adamw.AdamWConfig()):
    ctx = ShardCtx(mesh)

    def train_step(state: adamw.TrainState, batch):
        def lf(params):
            loss, metrics = M.loss_fn(cfg, params, batch, ctx)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(
            state.params)
        new_state = adamw.apply_updates(opt, state, grads)
        return new_state, {**metrics, "loss": loss,
                           "gnorm": adamw.global_norm(grads)}

    return train_step


def train_arguments(cfg: mcfg.ModelConfig, cell: mcfg.ShapeCell, mesh):
    """(abstract_args, in_shardings, out_shardings) for the train step."""
    spec = M.model_spec(cfg)
    params = abstract(spec, cfg.policy.param_dtype)
    state = adamw.abstract_state(params, cfg.policy.moment_dtype)
    psh = shardings(spec, mesh, cfg.policy.param_dtype)
    ssh = adamw.state_shardings(psh, mesh)
    bsh = batch_shardings(cfg, cell, mesh)
    metr_sh = {k: _replicated(mesh)
               for k in ("ce", "aux", "loss", "gnorm")}
    return ((state, batch_abstract(cfg, cell)), (ssh, bsh), (ssh, metr_sh))


# ------------------------------------------------------------------ serve
SERVE_DTYPE = jnp.bfloat16


def make_prefill_step(cfg: mcfg.ModelConfig, mesh):
    ctx = ShardCtx(mesh)

    def prefill_step(params, cache, batch):
        return M.prefill(cfg, params, batch.get("tokens"), cache,
                         patches=batch.get("patches"),
                         frames=batch.get("frames"), ctx=ctx)

    return prefill_step


def make_decode_step(cfg: mcfg.ModelConfig, mesh):
    ctx = ShardCtx(mesh)

    def decode_step(params, cache, tokens, pos):
        return M.decode_step(cfg, params, cache, tokens, pos, ctx=ctx)

    return decode_step


def serve_params(cfg: mcfg.ModelConfig, mesh):
    spec = M.model_spec(cfg)
    return abstract(spec, SERVE_DTYPE), shardings(spec, mesh, SERVE_DTYPE)


def serve_arguments(cfg: mcfg.ModelConfig, cell: mcfg.ShapeCell, mesh):
    """Abstract args + shardings for prefill (kind='prefill') or decode."""
    B, S = cell.global_batch, cell.seq_len
    params, psh = serve_params(cfg, mesh)
    cache = M.abstract_cache(cfg, B, S)
    csh = shardings(M.cache_spec(cfg, B, S), mesh, cfg.policy.cache_dtype)
    ids_sh = named_sharding(mesh, (B,), ("batch",))
    if cell.kind == "prefill":
        batch = batch_abstract(cfg, cell)
        bsh = batch_shardings(cfg, cell, mesh)
        return ((params, cache, batch), (psh, csh, bsh), (ids_sh, csh))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    tsh = named_sharding(mesh, (B, 1), ("batch", None))
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return ((params, cache, tokens, pos),
            (psh, csh, tsh, _replicated(mesh)),
            (ids_sh, csh))


def lease_arguments(cfg: mcfg.ModelConfig, cell: mcfg.ShapeCell, mesh, W: int):
    """Args/shardings for the cross-pod lease-sync window (variant leaseW)."""
    from repro.sharding import named_sharding
    spec = M.model_spec(cfg)
    params = abstract(spec, cfg.policy.param_dtype)
    state = adamw.abstract_state(params, cfg.policy.moment_dtype)
    psh = shardings(spec, mesh, cfg.policy.param_dtype)
    ssh = adamw.state_shardings(psh, mesh)
    ab = batch_abstract(cfg, cell)
    batches = {k: jax.ShapeDtypeStruct((W,) + v.shape, v.dtype)
               for k, v in ab.items()}
    ax = {"tokens": (None, "batch", None), "labels": (None, "batch", None),
          "frames": (None, "batch", None, None),
          "patches": (None, "batch", None, None)}
    bsh = {k: named_sharding(mesh, v.shape, ax[k])
           for k, v in batches.items()}
    return ((state, batches), (ssh, bsh), (ssh, _replicated(mesh)))


def build_cell(cfg: mcfg.ModelConfig, cell: mcfg.ShapeCell, mesh,
               variant: str = "base"):
    """Returns (fn, abstract_args, in_shardings, out_shardings, donate)."""
    if variant.startswith("lease") and cell.kind == "train":
        from repro.coherence.lease_sync import LeaseConfig, make_lease_window_step
        from repro.optim import adamw as _adamw
        W = int(variant[len("lease"):] or 4)
        fn = make_lease_window_step(cfg, mesh, _adamw.AdamWConfig(),
                                    LeaseConfig(wr_lease=W))
        args, insh, outsh = lease_arguments(cfg, cell, mesh, W)
        return fn, args, insh, outsh, (0,)
    if cell.kind == "train":
        fn = make_train_step(cfg, mesh)
        args, insh, outsh = train_arguments(cfg, cell, mesh)
        return fn, args, insh, outsh, (0,)
    if cell.kind == "prefill":
        fn = make_prefill_step(cfg, mesh)
        args, insh, outsh = serve_arguments(cfg, cell, mesh)
        return fn, args, insh, outsh, (1,)
    fn = make_decode_step(cfg, mesh)
    args, insh, outsh = serve_arguments(cfg, cell, mesh)
    return fn, args, insh, outsh, (1,)
