"""Unified coherence telemetry: one counter block for simulator and service.

MGSim/MGMark's lesson is that coherence studies need ONE instrumented
component with uniform counters; the fabric therefore reports the exact
counter names of the hierarchy simulator (``repro.core.engine.COUNTERS``)
plus a few service-level extras, so a production trace and a simulated trace
are directly comparable row-for-row.

Name mapping (service <-> simulator):
  l1_*  = ReplicaCache (a replica's private tier, the CU's L1)
  l2_*  = SharedCache  (the node-shared tier, the GPU's L2)
  *_mm  = TSUFabric    (the sharded TSU + main-memory authority)
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core import engine


@dataclasses.dataclass
class FabricStats:
    """Counter block; field names are a superset of ``engine.COUNTERS``."""

    # --- simulator-compatible counters (engine.COUNTERS) ---
    reads: int = 0            # client read ops
    writes: int = 0           # client write ops
    l1_hits: int = 0          # replica-tier lease hits
    l2_hits: int = 0          # shared-tier lease hits
    l1_to_l2: int = 0         # replica misses + write-throughs descending
    l2_to_mm: int = 0         # fabric (TSU+MM) accesses
    coh_miss_l1: int = 0      # replica tag hit, lease expired (self-inval)
    coh_miss_l2: int = 0      # shared tag hit, lease expired (self-inval)
    wb_evictions: int = 0     # always 0: the fabric is write-through
    inval_msgs: int = 0       # always 0: HALCONE sends no invalidations
    pcie_blocks: int = 0      # MM accesses routed to a non-home TSU shard
    # Fig-10 per-link traffic (state.link_bytes shared with the simulator):
    # inter-GPU bytes are pure data for this fabric — no invalidation
    # component can ever be added (inval_msgs is 0 by construction).
    bytes_l1_l2: int = 0      # replica<->shared link bytes
    bytes_l2_mm: int = 0      # shared<->TSU/MM link bytes
    bytes_inter_gpu: int = 0  # cross-shard (non-home TSU) link bytes
    # --- service extras ---
    write_throughs: int = 0   # queue drains that reached the fabric
    self_invalidations: int = 0  # expired lines dropped (coh_miss_l1 + l2)
    compulsory: int = 0       # replica misses with no tag present
    refetches: int = 0        # replica fills from below (shared or MM)
    capacity_evictions: int = 0  # victim-way displacements of live lines
    tsu_evictions: int = 0    # TSU set overflow victims (memts reinit to 0)
    overflow_reinits: int = 0 # 16-bit timestamp wraps (Algorithm: reinit)
    fences: int = 0           # barrier ops (kernel-boundary cts jump)
    fast_read_batches: int = 0  # read_batch calls served entirely by the
                              # replica tier (every key a lease hit) — part
                              # of the stats block so backend/sharded
                              # stats-equality assertions cover it
    write_batches: int = 0    # non-empty write_batch calls (ONE batch
                              # boundary each, DESIGN.md §11) — host-side
                              # like fast_read_batches, so stats-equality
                              # pins the write path's batch boundary too

    def bump(self, name: str, by: int = 1) -> None:
        setattr(self, name, getattr(self, name) + by)

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)

    def engine_view(self) -> Dict[str, int]:
        """Only the simulator-shared counters, in engine.COUNTERS order."""
        d = self.to_dict()
        return {k: d[k] for k in engine.COUNTERS}


# The fabric's telemetry must never drift from the simulator's.
_missing = set(engine.COUNTERS) - {f.name for f in
                                   dataclasses.fields(FabricStats)}
assert not _missing, f"FabricStats lost engine counters: {_missing}"


# ----------------------------------------------- device counter-vector layout
# The array backends (coherence/fabric/arrays.py op-scan + the batched
# grant pipeline in coherence/fabric/pipeline.py) accumulate counters as
# one int32 vector per fabric / per replica; these tuples are the ONE
# definition of that vector's layout.  wb_evictions / inval_msgs are 0 by
# construction (the paper's claim) and fast_read_batches / write_batches
# are host-side batch-boundary counts, so none of the four appear here.
G_KEYS = ("reads", "writes", "l1_hits", "l2_hits", "l1_to_l2", "l2_to_mm",
          "coh_miss_l1", "coh_miss_l2", "pcie_blocks", "write_throughs",
          "self_invalidations", "compulsory", "refetches",
          "capacity_evictions", "tsu_evictions", "overflow_reinits",
          "fences", "bytes_l1_l2", "bytes_l2_mm", "bytes_inter_gpu")
# the per-replica mirror subset (host ReplicaCache.stats semantics)
R_KEYS = ("reads", "writes", "l1_hits", "l2_hits", "l1_to_l2",
          "coh_miss_l1", "coh_miss_l2", "self_invalidations", "compulsory",
          "refetches", "capacity_evictions", "write_throughs")
GI = {k: i for i, k in enumerate(G_KEYS)}
RI = {k: i for i, k in enumerate(R_KEYS)}

_unknown = (set(G_KEYS) | set(R_KEYS)) - {f.name for f in
                                          dataclasses.fields(FabricStats)}
assert not _unknown, f"counter-vector keys missing from FabricStats: {_unknown}"
