"""Token-choice top-k MoE with capacity-based scatter dispatch.

Dispatch avoids the classic [T, E, C] one-hot (O(T*E*C) memory): we compute each
token's position-in-expert with a cumsum over a [T*k, E] int32 one-hot, then
scatter token embeddings into an [E*C, D] buffer.  Experts are sharded over the
"model" mesh axis (expert parallelism); capacity over "data".  GSPMD inserts the
dispatch collectives — replaced by explicit all_to_all in the §Perf hillclimb.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import swiglu
from repro.models.params import P
import repro.sharding as sharding
from repro.sharding import NOSHARD


def moe_spec(cfg: ModelConfig) -> dict:
    D, E = cfg.d_model, cfg.n_experts
    F = cfg.d_ff_expert or cfg.d_ff
    s = {
        "router": P((D, E), ("embed", None)),
        "wg": P((E, D, F), ("experts", "embed", "expert_mlp")),
        "wi": P((E, D, F), ("experts", "embed", "expert_mlp")),
        "wo": P((E, F, D), ("experts", "expert_mlp", "embed")),
    }
    if cfg.n_shared_experts:
        Fs = F * cfg.n_shared_experts
        s["shared"] = {
            "wg": P((D, Fs), ("embed", "mlp")),
            "wi": P((D, Fs), ("embed", "mlp")),
            "wo": P((Fs, D), ("mlp", "embed")),
        }
    return s


def capacity_for(cfg: ModelConfig, n_tokens: int) -> int:
    c = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, (c + 7) // 8 * 8)


def moe_apply(cfg: ModelConfig, p: dict, h, ctx=NOSHARD):
    """h: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    Two dispatch paths:
      * shard_map (default on a mesh with a "model" axis): per-device local
        scatter + ONE all_to_all over the expert-parallel axis + local expert
        compute.  Wire bytes per layer ~ 4x the dispatch buffer.
      * GSPMD global-scatter fallback: correct everywhere (CPU smoke tests),
        but the partitioner lowers the global scatter to a partial-buffer
        all-reduce PER LAYER (~20 GB x 59 layers x 3 passes on deepseek-v2 —
        the §Perf Pair-A baseline pathology).
    """
    if (ctx.mesh is not None and cfg.moe_shard_map
            and "model" in ctx.mesh.axis_names
            and cfg.n_experts % dict(zip(ctx.mesh.axis_names,
                                         ctx.mesh.devices.shape))["model"] == 0):
        return _moe_shard_map(cfg, p, h, ctx)
    return _moe_gspmd(cfg, p, h, ctx)


def _moe_gspmd(cfg: ModelConfig, p: dict, h, ctx=NOSHARD):
    B, S, D = h.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    C = capacity_for(cfg, T)
    cd = h.dtype
    x = h.reshape(T, D)

    x = ctx.constrain(x, "tokens", None)
    logits = (x @ p["router"].astype(cd)).astype(jnp.float32)      # [T, E]
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                           # [T, k]
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    # Switch-style load-balance aux loss
    f_e = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0) / (T * k)
    aux = E * jnp.sum(f_e * gates.mean(0))

    fe = ctx.constrain(topi.reshape(T * k), "tokens")              # flat experts
    onehot = (fe[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
    onehot = ctx.constrain(onehot, "tokens", None)
    pos_all = jnp.cumsum(onehot, axis=0) - 1                       # [T*k, E]
    pos_all = ctx.constrain(pos_all, "tokens", None)
    mypos = jnp.take_along_axis(pos_all, fe[:, None], axis=1)[:, 0]
    keep = mypos < C
    dest = jnp.where(keep, fe * C + mypos, E * C)                  # drop row E*C

    x_rep = ctx.constrain(jnp.repeat(x, k, axis=0), "tokens", None)  # [T*k, D]
    buf = jnp.zeros((E * C + 1, D), cd).at[dest].set(x_rep, mode="drop")
    xe = ctx.constrain(buf[: E * C].reshape(E, C, D),
                       "experts", "capacity", None)

    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(cd))
    u = jnp.einsum("ecd,edf->ecf", xe, p["wi"].astype(cd))
    act = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
    ye = jnp.einsum("ecf,efd->ecd", act, p["wo"].astype(cd))
    ye = ctx.constrain(ye, "experts", "capacity", None)

    y_pad = jnp.concatenate([ye.reshape(E * C, D),
                             jnp.zeros((1, D), cd)], axis=0)
    y_tok = y_pad[dest] * (keep[:, None] * topv.reshape(T * k)[:, None]).astype(cd)
    out = y_tok.reshape(T, k, D).sum(axis=1)

    if cfg.n_shared_experts:
        sp = p["shared"]
        out = out + swiglu(x, sp["wg"], sp["wi"], sp["wo"], cd)
    return out.reshape(B, S, D), aux


# -------------------------------------------------- shard_map dispatch path
def _moe_shard_map(cfg: ModelConfig, p: dict, h, ctx):
    """Expert parallelism with explicit collectives (the §Perf fix).

    Layout: tokens manual over (pod,data,model); experts over "model"; expert
    weights FSDP-gathered (bf16) inside; ONE all_to_all each way over "model".
    shard_map's transpose turns the weight all_gathers into reduce-scatters
    for the gradients — no per-layer gradient all-reduce.
    """
    from jax.sharding import PartitionSpec as P

    mesh = ctx.mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    m = sizes["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_dp = 1
    for a in dp_axes:
        n_dp *= sizes[a]
    n_dev = n_dp * m
    B, S, D = h.shape
    E, k = cfg.n_experts, cfg.top_k
    E_loc = E // m
    cd = h.dtype
    T = B * S
    if T % n_dev or S % m or B % n_dp:
        return _moe_gspmd(cfg, p, h, ctx)
    t_loc = T // n_dev
    C = capacity_for(cfg, t_loc)                       # per-device capacity

    from repro.sharding import partition_spec as pspec_of
    wg_spec = pspec_of(mesh, p["wg"].shape, ("experts", "embed", "expert_mlp"))
    wo_spec = pspec_of(mesh, p["wo"].shape, ("experts", "expert_mlp", "embed"))
    r_spec = pspec_of(mesh, p["router"].shape, ("embed", None))
    def _axes_of(spec, dim):
        if len(spec) <= dim or spec[dim] is None:
            return ()
        e = spec[dim]
        return e if isinstance(e, tuple) else (e,)

    gather_axes = _axes_of(wg_spec, 1)
    router_axes = _axes_of(r_spec, 0)

    def local(x, router, wg, wi, wo):
        # x: [B_loc, S_loc, D]; weights: local shards
        xf = x.reshape(-1, D)                          # [t_loc, D]
        if router_axes:
            router = jax.lax.all_gather(router, router_axes, axis=0,
                                        tiled=True)
        if gather_axes:
            wg = jax.lax.all_gather(wg, gather_axes, axis=1, tiled=True)
            wi = jax.lax.all_gather(wi, gather_axes, axis=1, tiled=True)
            wo = jax.lax.all_gather(wo, gather_axes, axis=2, tiled=True)
        logits = (xf @ router.astype(cd)).astype(jnp.float32)
        gates = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(gates, k)
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        f_e = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(
            1.0) / (t_loc * k)
        aux = E * jnp.sum(f_e * gates.mean(0))
        aux = jax.lax.pmean(aux, dp_axes + ("model",))

        fe = topi.reshape(t_loc * k)
        onehot = (fe[:, None] == jnp.arange(E)[None, :]).astype(jnp.int32)
        pos = jnp.cumsum(onehot, axis=0) - 1
        mypos = jnp.take_along_axis(pos, fe[:, None], axis=1)[:, 0]
        keep = mypos < C
        dest = jnp.where(keep, fe * C + mypos, E * C)
        x_rep = jnp.repeat(xf, k, axis=0)
        buf = jnp.zeros((E * C + 1, D), cd).at[dest].set(x_rep, mode="drop")
        # dispatch: one all_to_all over the expert-parallel axis
        send = buf[: E * C].reshape(m, E_loc * C, D)
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=True)  # [m, E_loc*C, D]
        xe = recv.reshape(m, E_loc, C, D).transpose(1, 0, 2, 3) \
                 .reshape(E_loc, m * C, D)
        g = jnp.einsum("ecd,edf->ecf", xe, wg.astype(cd))
        u = jnp.einsum("ecd,edf->ecf", xe, wi.astype(cd))
        act = jax.nn.silu(g.astype(jnp.float32)).astype(cd) * u
        ye = jnp.einsum("ecf,efd->ecd", act, wo.astype(cd))
        # inverse all_to_all back to token owners
        back = ye.reshape(E_loc, m, C, D).transpose(1, 0, 2, 3) \
                 .reshape(m, E_loc * C, D)
        mine = jax.lax.all_to_all(back, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        y_pad = jnp.concatenate([mine.reshape(E * C, D),
                                 jnp.zeros((1, D), cd)], axis=0)
        y_tok = y_pad[dest] * (keep[:, None]
                               * topv.reshape(t_loc * k)[:, None]).astype(cd)
        out = y_tok.reshape(t_loc, k, D).sum(axis=1)
        return out.reshape(x.shape), aux

    x_spec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0], "model", None)
    out, aux = sharding.shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, r_spec, wg_spec, wg_spec, wo_spec),
        out_specs=(x_spec, P()),
        axis_names=set(dp_axes) | {"model"}, check_vma=False)(
            h, p["router"], p["wg"], p["wi"], p["wo"])

    if cfg.n_shared_experts:
        sp = p["shared"]
        B_, S_, D_ = h.shape
        out = out + swiglu(h.reshape(-1, D_), sp["wg"], sp["wi"], sp["wo"],
                           cd).reshape(B_, S_, D_)
    return out, aux
