"""Array-native coherence state layer: the ONE implementation of the
hierarchy transition rules.

HALCONE's pitch is that every coherence decision is local arithmetic over
``[wts, rts]`` leases — so the whole hierarchy (L1/replica tier, L2/shared
tier, TSU) is representable as a handful of int32 arrays plus pure, batched
transition functions.  This module holds exactly that:

  * ``TierState``  — one set-associative lease tier ([N, S, W+1] arrays with
    a trailing trash way for masked scatters) — the simulator's L1 and L2
    AND the fabric's replica/shared client tiers.
  * ``TSUState``   — the timestamp-storage-unit rows (tag + 16-bit memts) —
    the simulator's per-HBM-stack TSU AND the fabric's per-shard MM+TSU
    table (shaped ``[n_shards, 1, capacity+1]``, i.e. one fully-associative
    set per shard).
  * transition functions — probe / victim selection / the TSU grant
    (Algorithm 3 + 16-bit overflow reinit) / the fused tier probe+install
    (Algorithms 1, 2, 4, 5 via ``kernels.lease_probe``) / the TSU commit.

Both consumers import from here and re-derive NOTHING:

  * ``core/engine.py`` — the timing simulator: one ``round_step`` scan,
    requests batched over all CUs.
  * ``coherence/fabric/arrays.py`` — the production fabric: one op-scan,
    requests batched per serving/training batch.

All timestamp arithmetic is ``repro.core.protocol``; all fused probe+install
math is ``kernels.lease_probe`` (compiled Pallas on TPU/GPU, interpret
fallback on CPU — bit-identical, see DESIGN.md §5).  No other module may
implement these rules (DESIGN.md §7 backend-parity contract).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core import protocol
from repro.kernels.lease_probe import lease_probe

INVALID = jnp.int32(-1)

# ------------------------------------------------------- link traffic (Fig 10)
# Every hierarchy hop moves one data block; directory invalidations (HMG)
# are control-sized messages.  These two constants + ``link_bytes`` are the
# ONE definition of the paper's Fig-10 per-link traffic accounting: the
# timing simulator (engine.COUNTERS) and the production fabric
# (FabricStats) both report bytes through this helper, so a simulated
# trace and a served trace decompose identically.
BLOCK_BYTES = 64        # one cache block / KV line on any data link
CTRL_BYTES = 8          # one invalidation / control message (HMG only)


def link_bytes(l1_l2_msgs, l2_mm_msgs, inter_gpu_blocks, inval_msgs=0):
    """Per-link byte counters (L1<->L2, L2<->MM, inter-GPU).

    Works on python ints and on traced arrays alike.  HALCONE's headline
    (Fig. 10): ``inval_msgs`` is 0 by construction, so its inter-GPU bytes
    are pure data; HMG pays ``CTRL_BYTES`` per invalidation on the same
    low-bandwidth links.
    """
    return (l1_l2_msgs * BLOCK_BYTES,
            l2_mm_msgs * BLOCK_BYTES,
            inter_gpu_blocks * BLOCK_BYTES + inval_msgs * CTRL_BYTES)


# ----------------------------------------------------------------- states
class TierState(NamedTuple):
    """One set-associative lease tier.

    Arrays are ``[N, S, W+1]`` (N caches x S sets x W ways + 1 trash way
    used as the target of masked scatters; a real tag never lands there).
    ``cts`` is the per-cache logical clock ``[N]``.
    """

    tag: jnp.ndarray     # int32, INVALID = empty
    wts: jnp.ndarray
    rts: jnp.ndarray
    ver: jnp.ndarray     # data version carried by the line
    lru: jnp.ndarray     # victim score (higher = more recently used)
    cts: jnp.ndarray     # [N] logical clocks

    @property
    def n_ways(self) -> int:
        return self.tag.shape[-1] - 1


class TSUState(NamedTuple):
    """Timestamp-storage-unit rows: ``[H, S, W+1]`` tag + memts."""

    tag: jnp.ndarray
    memts: jnp.ndarray

    @property
    def n_ways(self) -> int:
        return self.tag.shape[-1] - 1


def init_tier(n: int, sets: int, ways: int) -> TierState:
    shp = (n, sets, ways + 1)
    z = lambda: jnp.zeros(shp, jnp.int32)
    return TierState(tag=jnp.full(shp, INVALID), wts=z(), rts=z(), ver=z(),
                     lru=z(), cts=jnp.zeros((n,), jnp.int32))


def init_tsu(h: int, sets: int, ways: int) -> TSUState:
    shp = (h, sets, ways + 1)
    return TSUState(tag=jnp.full(shp, INVALID),
                    memts=jnp.zeros(shp, jnp.int32))


# ----------------------------------------------------------------- probes
def probe(tag_arr, idx, set_idx, addr):
    """Tag-only probe over the live ways of each request's set.

    tag_arr: [N, S, W+1]; idx/set_idx/addr: [n].  Returns (tag_hit, way) —
    ``way`` is the FIRST matching way (argmax over the match mask), the
    convention every consumer and the Pallas kernel share.
    """
    rows = tag_arr[idx, set_idx][..., :-1]          # [n, W]
    eq = rows == addr[..., None]
    return eq.any(-1), jnp.argmax(eq, -1)


def victim(tag_arr, score_arr, idx, set_idx):
    """Victim way: invalid ways first, else the minimum score; ties break to
    the FIRST such way (argmin), matching the host stores' strict-< scan."""
    rows_t = tag_arr[idx, set_idx][..., :-1]
    rows_s = score_arr[idx, set_idx][..., :-1]
    score = jnp.where(rows_t == INVALID, jnp.int32(-2 ** 30), rows_s)
    return jnp.argmin(score, -1)


def victim_lex(tag_arr, primary, secondary, idx, set_idx):
    """Lexicographic victim: invalid first, else min primary, ties broken by
    min secondary (the fabric TSU's dict-order rule: among equal-``memts``
    entries the earliest-allocated is evicted)."""
    rows_t = tag_arr[idx, set_idx][..., :-1]
    rows_p = primary[idx, set_idx][..., :-1]
    rows_s = secondary[idx, set_idx][..., :-1]
    invalid = rows_t == INVALID
    p = jnp.where(invalid, jnp.int32(-2 ** 30), rows_p)
    pmin = jnp.min(p, -1, keepdims=True)
    s = jnp.where(p == pmin, rows_s, jnp.int32(2 ** 30))
    return jnp.argmin(s, -1)


# ------------------------------------------------------------- TSU grant
class TSUGrant(NamedTuple):
    wts: jnp.ndarray        # the [wts, rts] lease the TSU grants
    rts: jnp.ndarray
    new_memts: jnp.ndarray  # the clock the entry holds afterwards
    overflow: jnp.ndarray   # bool: the 16-bit reinit fired


def tsu_lease(memts, is_write, rd_lease, wr_lease) -> TSUGrant:
    """The TSU decision (Algorithm 3, Fig. 5 conventions) for a batch of
    requests against their entries' current clocks, including the 16-bit
    overflow reinit (DESIGN.md §3a): a grant that would push ``memts`` past
    ``protocol.TS_MAX`` restarts the entry at 0 and is re-served as a first
    read — wts=0, rts=lease, memts'=rts (write-through keeps MM correct).

    memts: [n] current entry clocks (0 for fresh/missing entries);
    is_write: [n] bool; rd_lease/wr_lease: scalars or [n].
    """
    r_lease, r_memts = protocol.mm_read(memts, rd_lease)
    w_lease, w_memts = protocol.mm_write(memts, wr_lease)
    wts = jnp.where(is_write, w_lease.wts, r_lease.wts)
    rts = jnp.where(is_write, w_lease.rts, r_lease.rts)
    new_memts = jnp.where(is_write, w_memts, r_memts)
    ovf = new_memts > protocol.TS_MAX
    wts = jnp.where(ovf, 0, wts)
    rts = jnp.where(ovf, jnp.where(is_write, wr_lease, rd_lease), rts)
    new_memts = jnp.where(ovf, rts, new_memts)
    return TSUGrant(wts, rts, new_memts, ovf)


def tsu_commit_scatter(tsu: TSUState, idx, set_idx, way, addr, new_memts,
                       active, tag_hit) -> TSUState:
    """The simulator's TSU state update: same-round requests to one slot are
    resolved by scatter-max (same-tick semantics, paper §3.2 — the largest
    extension wins; on an eviction-install the largest tag keeps the slot).
    Inactive requests are routed to the trash way.
    """
    tw = jnp.where(active, way, tsu.n_ways)
    tag = tsu.tag.at[idx, set_idx, tw].max(
        jnp.where(active, addr, INVALID))
    cleared = jnp.where(active & ~tag_hit, 0, tsu.memts[idx, set_idx, tw])
    memts = tsu.memts.at[idx, set_idx, tw].set(
        jnp.where(active, jnp.maximum(cleared, 0), cleared))
    memts = memts.at[idx, set_idx, tw].max(jnp.where(active, new_memts, 0))
    return TSUState(tag=tag, memts=memts)


def tsu_commit_exact(tsu: TSUState, idx, set_idx, way, addr, new_memts,
                     active) -> TSUState:
    """The fabric's TSU state update: one op at a time, so the slot is
    written exactly (the host dict's replace semantics — no scatter-max
    races to resolve).  Inactive ops are routed to the trash way."""
    tw = jnp.where(active, way, tsu.n_ways)
    return TSUState(
        tag=tsu.tag.at[idx, set_idx, tw].set(
            jnp.where(active, addr, tsu.tag[idx, set_idx, tw])),
        memts=tsu.memts.at[idx, set_idx, tw].set(
            jnp.where(active, new_memts, tsu.memts[idx, set_idx, tw])))


# -------------------------------------------------- tier probe + install
def install_lease(cts, wts_resp, rts_resp):
    """Install math alone (Algorithms 1/2 + writer clock), for fills whose
    way is already known: returns (new_wts, new_rts, new_cts).  The same
    arithmetic ``tier_probe`` fuses with the probe via the Pallas kernel."""
    lease = protocol.install(cts, wts_resp, rts_resp)
    return lease.wts, lease.rts, protocol.cts_after_write(cts, lease.wts)


def tier_probe(tier: TierState, idx, set_idx, addr, mwts, mrts):
    """Fused probe + install math for one tier — the per-request coherence
    action, served by the Pallas lease-probe kernel.

    Gathers each request's set row from ``tier`` and runs the kernel:
    tag compare (first-match way), lease validity (``protocol.valid``),
    Algorithm 1/2 install (``protocol.install``) of the response lease
    ``(mwts, mrts)`` arriving from the level below, and the writer clock
    advance (``protocol.cts_after_write``).

    Returns (tag_hit, hit, way, row_rts, new_wts, new_rts, new_cts); see
    ``kernels.lease_probe`` for the exact contract.  Callers that only need
    the probe half may pass zeros for (mwts, mrts) and ignore the install
    outputs; callers that only need the install half ignore the hit outputs.
    """
    return lease_probe(tier.tag[idx, set_idx][..., :-1],
                       tier.rts[idx, set_idx][..., :-1],
                       tier.cts[idx], addr, mwts, mrts)
