"""Load-generator tests: bounded Zipf correctness (the fabric_bench
wrap-bug fix), arrival processes, and trace record/replay."""
import numpy as np
import pytest

from repro.runtime.loadgen import (BoundedZipf, RequestTrace, bounded_zipf,
                                   burst_arrivals, diurnal_arrivals,
                                   poisson_arrivals, synthesize)


# ------------------------------------------------------------- bounded Zipf
def test_bounded_zipf_support_and_determinism():
    z = BoundedZipf(37, a=1.5)
    rng = np.random.default_rng(0)
    s = z.sample(rng, size=20_000)
    assert s.min() >= 0 and s.max() < 37
    assert s.dtype == np.int64
    # scalar draw
    k = z.sample(np.random.default_rng(1))
    assert isinstance(k, int) and 0 <= k < 37
    # same seed -> same stream
    s2 = BoundedZipf(37, a=1.5).sample(np.random.default_rng(0), size=20_000)
    np.testing.assert_array_equal(s, s2)


def test_bounded_zipf_pmf_is_truncated_law():
    z = BoundedZipf(64, a=1.5)
    p = z.pmf()
    assert p.shape == (64,) and abs(p.sum() - 1.0) < 1e-12
    # pmf(k) ∝ 1/(k+1)^a: exact ratio between rank 0 and rank 1
    assert p[0] / p[1] == pytest.approx(2.0 ** 1.5, rel=1e-12)
    # empirical frequencies converge on the analytic pmf
    s = z.sample(np.random.default_rng(3), size=200_000)
    freq = np.bincount(s, minlength=64) / len(s)
    assert abs(freq[0] - p[0]) < 0.01


def test_bounded_zipf_is_skewed_where_modulo_wrap_is_not():
    """The old `rng.zipf(a) % n` idiom folds the unbounded tail back onto
    the support, adding a near-uniform term that flattens the skew.  The
    bounded sampler's head mass must dominate the wrapped sampler's."""
    n, a = 32, 1.5
    rng = np.random.default_rng(11)
    wrapped = (rng.zipf(a, size=100_000) - 1) % n
    bounded = BoundedZipf(n, a).sample(np.random.default_rng(11),
                                       size=100_000)
    top4 = lambda s: np.sort(np.bincount(s, minlength=n))[-4:].sum() / len(s)
    assert top4(bounded) > top4(wrapped)
    # and the bounded tail is strictly thinner than the wrapped tail
    tail = lambda s: np.mean(s >= n // 2)
    assert tail(bounded) < tail(wrapped)


def test_bounded_zipf_cache_and_validation():
    assert bounded_zipf(16, 1.3) is bounded_zipf(16, 1.3)
    with pytest.raises(ValueError):
        BoundedZipf(0)
    with pytest.raises(ValueError):
        BoundedZipf(8, a=0.0)


# -------------------------------------------------------- arrival processes
@pytest.mark.parametrize("fn,kw", [
    (poisson_arrivals, {}),
    (diurnal_arrivals, {"amplitude": 0.9}),
    (burst_arrivals, {"burst": 8.0}),
])
def test_arrivals_nondecreasing(fn, kw):
    rng = np.random.default_rng(5)
    t = fn(rng, 4000, rate=50.0, **kw)
    assert t.shape == (4000,)
    assert np.all(np.diff(t) >= 0) and t[0] > 0


@pytest.mark.parametrize("fn,kw", [
    (poisson_arrivals, {}),
    (diurnal_arrivals, {"amplitude": 0.9}),
])
def test_arrivals_rate_scaled(fn, kw):
    # mean offered rate lands near the nominal rate (loose: 25%); burst
    # is excluded — flash crowds push its realized mean ABOVE nominal by
    # design (hot-state arrivals come 8x faster)
    rng = np.random.default_rng(5)
    t = fn(rng, 4000, rate=50.0, **kw)
    assert 4000 / t[-1] == pytest.approx(50.0, rel=0.25)
    t_burst = burst_arrivals(np.random.default_rng(5), 4000, rate=50.0)
    assert 4000 / t_burst[-1] > 50.0 * 0.9


def test_diurnal_has_rate_swing():
    rng = np.random.default_rng(9)
    t = diurnal_arrivals(rng, 6000, rate=100.0, amplitude=0.9, cycles=3.0)
    # instantaneous rate via gaps: the fastest decile of gaps should be
    # far tighter than the slowest (trough rate = 0.1x peak rate = 19x gap)
    gaps = np.diff(t)
    assert np.quantile(gaps, 0.9) / np.quantile(gaps, 0.1) > 4.0


def test_process_validation():
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError):
        poisson_arrivals(rng, 10, rate=0.0)
    with pytest.raises(ValueError):
        diurnal_arrivals(rng, 10, rate=1.0, amplitude=1.5)
    with pytest.raises(ValueError):
        burst_arrivals(rng, 10, rate=1.0, burst=0.5)


# ------------------------------------------------------------------- traces
def test_synthesize_and_scaled_time_axis_only():
    tr = synthesize(500, 64, a=1.2, process="poisson", rate=20.0, seed=4)
    assert len(tr) == 500 and tr.n_keys == 64
    assert tr.kid.min() >= 0 and tr.kid.max() < 64
    assert tr.offered_rps == pytest.approx(20.0, rel=0.3)
    fast = tr.scaled(4.0)
    np.testing.assert_array_equal(fast.kid, tr.kid)   # identical key stream
    np.testing.assert_allclose(fast.t, tr.t / 4.0)
    assert fast.offered_rps == pytest.approx(tr.offered_rps * 4.0)
    assert fast.meta["scaled_by"] == 4.0
    with pytest.raises(ValueError):
        tr.scaled(0.0)
    with pytest.raises(ValueError):
        synthesize(10, 8, process="nope")


def test_trace_save_load_roundtrip(tmp_path):
    tr = synthesize(200, 32, process="burst", rate=10.0, seed=2)
    p = tmp_path / "traces" / "t.npz"
    tr.save(p)
    back = RequestTrace.load(p)
    np.testing.assert_array_equal(back.t, tr.t)
    np.testing.assert_array_equal(back.kid, tr.kid)
    assert back.n_keys == tr.n_keys
    assert back.meta["process"] == "burst" and back.meta["seed"] == 2


def test_trace_validation():
    with pytest.raises(ValueError):
        RequestTrace(t=np.array([1.0, 0.5]), kid=np.array([0, 0], np.int32),
                     n_keys=4)
    with pytest.raises(ValueError):
        RequestTrace(t=np.array([0.5, 1.0]), kid=np.array([0, 9], np.int32),
                     n_keys=4)
    with pytest.raises(ValueError):
        RequestTrace(t=np.array([0.5]), kid=np.array([0, 1], np.int32),
                     n_keys=4)
