"""llava-next-34b [vlm] — anyres tiling; backbone only, vision frontend is a
stub (input_specs supplies pre-projected patch embeddings).
[hf:llava-hf/llava-v1.6-*] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_head=128,
    d_ff=20480, vocab=64000, rope_theta=5e6,
    frontend="vision", n_patch_tokens=576,
)
