"""Fault-tolerant training runtime.

Production shape: checkpoint/restart (write-through manager), straggler
detection (per-step wall-time watchdog with EMA + threshold), simulated node
failures with elastic re-meshing (restore the same checkpoint under a smaller
mesh's shardings), and optional lease-synced local SGD.

On this CPU container the mesh is 1 device; the *logic* (restart, elastic
reshard, watchdog) is what tests exercise — the same code drives the 256/512
chip meshes via launch/train.py.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.coherence.fabric import (FabricBackend, FabricConfig,
                                    default_fabric)
from repro.coherence.lease_sync import LeaseClock
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.steps import make_train_step
from repro.models import init_model, model_shardings, model_spec
from repro.models.params import shardings as spec_shardings
from repro.optim import adamw
from repro.sharding import ShardCtx


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_period: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    straggler_ema: float = 0.9
    straggler_factor: float = 3.0       # step > factor*EMA => straggler event
    keep: int = 3


class Trainer:
    def __init__(self, cfg, mesh, opt: Optional[adamw.AdamWConfig] = None,
                 tcfg: TrainerConfig = TrainerConfig(),
                 data: Optional[SyntheticLM] = None,
                 fabric: Optional[FabricBackend] = None):
        self.cfg, self.mesh, self.tcfg = cfg, mesh, tcfg
        self.opt = opt or adamw.AdamWConfig(total_steps=tcfg.total_steps)
        self.data = data
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        # every checkpoint publish is a parameter write-through on the
        # coherence fabric (array backend): eval readers hold the previous
        # version on a ckpt_period-step lease instead of being invalidated.
        self.fabric = fabric if fabric is not None else default_fabric(
            FabricConfig(n_shards=1, max_in_flight=0))
        self.param_clock = LeaseClock(fabric=self.fabric)
        self.events: List[Dict] = []
        self._ema = None
        self._build(mesh)

    # --------------------------------------------------------- building
    def _build(self, mesh):
        self.mesh = mesh
        self.step_fn = jax.jit(make_train_step(self.cfg, mesh, self.opt))
        self.psh = spec_shardings(model_spec(self.cfg), mesh,
                                  self.cfg.policy.param_dtype)
        self.ssh = adamw.state_shardings(self.psh, mesh)
        # stable per-parameter-block fabric keys: each checkpoint publish
        # re-stamps the SAME keys (a republish storm — eval readers
        # self-invalidate on lease expiry, never via invalidations)
        self._param_keys = [
            "ckpt" + jax.tree_util.keystr(kp) for kp, _ in
            jax.tree_util.tree_flatten_with_path(self.psh)[0]]

    def init_state(self, seed: int = 0) -> adamw.TrainState:
        params = init_model(self.cfg, jax.random.PRNGKey(seed))
        params = jax.tree.map(jax.device_put, params, self.psh)
        return adamw.init_state(params, self.cfg.policy.moment_dtype)

    # ----------------------------------------------------------- loop
    def run(self, state: Optional[adamw.TrainState] = None,
            start_step: int = 0,
            fail_at: Optional[int] = None) -> Dict[str, Any]:
        """Train to total_steps.  fail_at simulates a node failure at that
        step (raises, then the caller — or resume() — restarts from ckpt)."""
        if state is None:
            state = self.init_state()
        losses = []
        step = start_step
        while step < self.tcfg.total_steps:
            batch = self.data.batch(step)
            t0 = time.time()
            if fail_at is not None and step == fail_at:
                raise RuntimeError(f"simulated node failure at step {step}")
            state, metrics = self.step_fn(state, batch)
            loss = float(metrics["loss"])
            dt = time.time() - t0
            self._watch(step, dt)
            losses.append(loss)
            step += 1
            if step % self.tcfg.ckpt_period == 0 or step == self.tcfg.total_steps:
                self.ckpt.save(step, state)
                # the checkpoint publish is a batched republish storm:
                # every parameter block's version stamp goes out as ONE
                # posted write_batch (the batched write pass, DESIGN.md
                # §11) and the fence drains + jumps the clocks, then the
                # window lease advances on the authority (mm_write)
                self.fabric.write_batch(
                    [(k, step) for k in self._param_keys], replica=0,
                    wr_lease=self.tcfg.ckpt_period)
                self.fabric.fence()
                lease = self.param_clock.on_sync(self.tcfg.ckpt_period,
                                                 version_tag=step)
                self.events.append({"kind": "param_lease", "step": step,
                                    "wts": int(lease.wts),
                                    "rts": int(lease.rts),
                                    "blocks": len(self._param_keys)})
        self.ckpt.wait()
        return {"state": state, "losses": losses, "events": self.events,
                "final_step": step,
                "fabric_stats": self.fabric.stats()}

    def resume(self, mesh=None, template: Optional[adamw.TrainState] = None,
               **kw) -> Dict[str, Any]:
        """Restart from the latest checkpoint — optionally under a NEW mesh
        (elastic scaling after node loss): shardings are rebuilt and arrays
        re-placed at restore time."""
        if mesh is not None:
            self._build(mesh)
            self.events.append({"kind": "elastic_remesh",
                                "devices": int(mesh.devices.size)})
        step = self.ckpt.latest_step()
        if template is None:
            template = self.init_state()
        state = self.ckpt.restore(step, template, self.ssh)
        self.events.append({"kind": "restore", "step": step})
        return self.run(state=state, start_step=step, **kw)

    # ------------------------------------------------------- watchdog
    def _watch(self, step: int, dt: float):
        if self._ema is None:
            self._ema = dt
            return
        if dt > self.tcfg.straggler_factor * self._ema and step > 3:
            self.events.append({"kind": "straggler", "step": step,
                                "dt": dt, "ema": self._ema})
        a = self.tcfg.straggler_ema
        self._ema = a * self._ema + (1 - a) * dt
