"""deepseek-v2-236b [moe] — MLA kv_lora=512, 2 shared + 160 routed top-6.
[arXiv:2405.04434] 60L d_model=5120 128H vocab=102400, expert d_ff=1536
(assignment's d_ff); layer-0 dense FFN uses the model's 12288.
Policy: bf16 optimizer moments (>=200B trick, DESIGN.md)."""
import jax.numpy as jnp

from repro.models.config import ModelConfig, Policy

CONFIG = ModelConfig(
    name="deepseek-v2-236b", family="moe",
    n_layers=60, d_model=5120, n_heads=128, n_kv_heads=128, d_head=128,
    d_ff=12288, vocab=102400, rope_theta=1e4,
    n_experts=160, top_k=6, n_shared_experts=2, d_ff_expert=1536,
    first_dense=1,
    q_lora=1536, kv_lora=512, nope_head_dim=128, rope_head_dim=64,
    v_head_dim=128,
    policy=Policy(param_dtype=jnp.bfloat16, moment_dtype=jnp.bfloat16),
)
