"""§5.4: sensitivity to (RdLease, WrLease) on the coherence-heavy Xtreme
suite.  Paper: widening |RdLease-WrLease| from 5 to 10 costs up to ~3%."""
import numpy as np

from benchmarks.common import cached, emit, timed
from repro.core import simulate
from repro.core.sysconfig import sm_wt_halcone
from repro.core.traces import XtremeSpec, xtreme

PAIRS = [(2, 10), (10, 2), (5, 10), (10, 5), (20, 10), (10, 20)]
SYS = dict(n_gpus=4, cus_per_gpu=32)


def run_all(force=False):
    def compute():
        out = {}
        spec = XtremeSpec(3, 24, 6)
        base = sm_wt_halcone(**SYS)
        ops, addrs = xtreme(base, spec)
        for rd, wr in PAIRS:
            cfg = sm_wt_halcone(rd_lease=rd, wr_lease=wr, **SYS)
            r, us = timed(simulate, cfg, ops, addrs)
            out[f"rd{rd}_wr{wr}"] = {"cycles": float(r["cycles"]), "us": us}
        return out

    return cached("lease_sensitivity", compute, force)


def main(force=False):
    data = run_all(force)
    best = min(v["cycles"] for v in data.values())
    for k, v in data.items():
        emit(f"lease/{k}", v["us"], f"vs_best={v['cycles']/best - 1:+.2%}")
    return data


if __name__ == "__main__":
    main()
