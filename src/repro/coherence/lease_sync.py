"""Lease-synchronized data parallelism — HALCONE's insight applied to
distributed training.

Mapping (DESIGN.md §2b): parameters are the shared cache blocks, each
data-parallel worker is a GPU with logical clock cts = its local step count,
the gradient all-reduce is the write-through, and ``wr_lease`` is the number
of local steps a worker may run on its cached (stale) parameters before the
lease expires and a sync refreshes them.  wr_lease=1 is exact synchronous DP;
wr_lease=W cuts the collective roofline term by ~W at bounded staleness
(local-SGD with Lamport ordering — timestamps from repro.core.protocol).

Two implementations:
  * ``make_lease_window_step`` — shard_map over the "data" axis ("model"
    stays auto-sharded): W local AdamW steps per window, one parameter
    all-reduce at the end.  This is the dry-run / production path.
  * ``VmappedWorkers`` — workers as a leading array axis (vmap), runnable on
    one CPU device; used by tests to check the math (W=1 == sync DP).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import model as M
from repro.optim import adamw
from repro.sharding import ShardCtx


@dataclasses.dataclass(frozen=True)
class LeaseConfig:
    wr_lease: int = 4            # local steps between write-throughs
    rd_lease: int = 4            # eval/readers may be this stale (steps)


class LeaseClock:
    """Lamport bookkeeping for the parameter store (host-level).

    Thin adapter over the coherence fabric: the parameter blob is one block
    in the sharded TSU service, and every window's write-through is an
    authority ``mm_write`` — so training's clock shares the 16-bit overflow
    reinit and the telemetry of the serving path instead of re-deriving the
    rules.  Takes any ``FabricBackend`` (default: the jitted array fabric);
    the legacy host ``TSUFabric`` is still accepted for the oracle tests.
    """

    PARAM_KEY = "params"

    def __init__(self, fabric=None):
        from repro.coherence.fabric import FabricConfig, default_fabric
        self.fabric = fabric if fabric is not None else default_fabric(
            FabricConfig(n_shards=1, max_in_flight=0))

    @property
    def memts(self) -> int:
        return self.fabric.memts(self.PARAM_KEY)

    def on_sync(self, wr_lease: int, version_tag=None):
        from repro.core import protocol
        from repro.coherence.fabric import FabricBackend
        if isinstance(self.fabric, FabricBackend):
            wts, rts, _ = self.fabric.mm_write(self.PARAM_KEY, version_tag,
                                               wr_lease=wr_lease)
            return protocol.Lease(wts, rts)  # the new param version
        grant = self.fabric.write(self.PARAM_KEY, version_tag,
                                  wr_lease=wr_lease)
        return protocol.Lease(grant.wts, grant.rts)


def make_lease_window_step(cfg, mesh, opt: adamw.AdamWConfig,
                           lease: LeaseConfig):
    """Cross-pod lease-synced training (the HALCONE deployment shape).

    Pods play the paper's GPUs: inside a pod, FSDP+TP run synchronously
    (auto axes); ACROSS pods, each pod runs ``wr_lease`` local steps on its
    lease of the parameters, then one write-through (param+moment psum over
    "pod").  Collective traffic across the inter-pod links drops ~W x
    (gradients never cross pods; parameters cross once per window).

    window_step(state, batches): batches leaves [W, B_pod, S] with the global
    batch dim sharded over ("data",) inside each pod.
    """
    from repro.sharding import rules_without
    W = lease.wr_lease
    # inside the manual-over-pod region, constraints may not mention "pod"
    ctx = ShardCtx(mesh, rules=rules_without("pod"))
    assert "pod" in mesh.axis_names, "lease window needs the multi-pod mesh"
    n_pod = dict(zip(mesh.axis_names, mesh.devices.shape))["pod"]

    def local_window(state: adamw.TrainState, batches):
        def one(st, batch):
            def lf(params):
                loss, _ = M.loss_fn(cfg, params, batch, ctx)
                return loss

            loss, grads = jax.value_and_grad(lf)(st.params)
            return adamw.apply_updates(opt, st, grads), loss

        state, losses = jax.lax.scan(one, state, batches)
        # write-through at lease expiry: average the diverged pod replicas
        avg = lambda t: jax.tree.map(
            lambda x: (jax.lax.psum(x.astype(jnp.float32), "pod")
                       / n_pod).astype(x.dtype), t)
        return adamw.TrainState(avg(state.params), avg(state.m),
                                avg(state.v), state.step), losses.mean()

    def window_step(state, batches):
        bspec = jax.tree.map(lambda _: P(None, "pod"), batches)
        sspec = jax.tree.map(lambda _: P(), state)
        import repro.sharding as sharding
        return sharding.shard_map(local_window, mesh=mesh,
                                  in_specs=(sspec, bspec),
                                  out_specs=(sspec, P()),
                                  axis_names={"pod"},
                                  check_vma=False)(state, batches)

    return window_step


class VmappedWorkers:
    """n_workers as an array axis on one device — the testable equivalent."""

    def __init__(self, cfg, opt: adamw.AdamWConfig, lease: LeaseConfig,
                 n_workers: int, key):
        self.cfg, self.opt, self.lease = cfg, opt, lease
        self.n = n_workers
        p0 = M.init_model(cfg, key)
        rep = lambda x: jnp.broadcast_to(x[None], (n_workers,) + x.shape)
        self.state = adamw.TrainState(
            params=jax.tree.map(rep, p0),
            m=jax.tree.map(lambda x: jnp.zeros((n_workers,) + x.shape,
                                               cfg.policy.moment_dtype), p0),
            v=jax.tree.map(lambda x: jnp.zeros((n_workers,) + x.shape,
                                               cfg.policy.moment_dtype), p0),
            step=jnp.zeros((n_workers,), jnp.int32))
        self.clock = LeaseClock()
        self.local_steps = 0
        self.collective_bytes = 0         # accounting for the lease claim

        def one(state, batch):
            def lf(params):
                return M.loss_fn(cfg, params, batch)[0]
            loss, grads = jax.value_and_grad(lf)(state.params)
            return adamw.apply_updates(opt, state, grads), loss

        self._local = jax.jit(jax.vmap(one))

        def sync(state):
            avg = lambda t: jax.tree.map(
                lambda x: jnp.broadcast_to(
                    x.astype(jnp.float32).mean(0, keepdims=True),
                    x.shape).astype(x.dtype), t)
            return adamw.TrainState(avg(state.params), avg(state.m),
                                    avg(state.v), state.step)

        self._sync = jax.jit(sync)

    def step(self, batches) -> float:
        """batches: per-worker batch dict with leading [n_workers] dim."""
        self.state, loss = self._local(self.state, batches)
        self.local_steps += 1
        if self.local_steps % self.lease.wr_lease == 0:
            self.state = self._sync(self.state)
            self.clock.on_sync(self.lease.wr_lease)
            self.collective_bytes += sum(
                x.nbytes // self.n for x in jax.tree.leaves(self.state.params))
        return float(loss.mean())
