"""HALCONE core: the paper's timestamp-coherence protocol, a vectorized
multi-GPU memory-hierarchy simulator, system configs, and trace generators."""
from repro.core import protocol, state, traces  # noqa: F401
from repro.core.engine import (COMPUTE, FENCE, NOP, READ, WRITE,  # noqa: F401
                               SimState, init_state, simulate, sweep)
from repro.core.sysconfig import (ALL_CONFIGS, SystemConfig,  # noqa: F401
                                  rdma_wb_hmg, rdma_wb_nc, sm_wb_nc,
                                  sm_wt_halcone, sm_wt_nc, stack_configs,
                                  static_key)
