"""Property-based tests (hypothesis) for the HALCONE protocol invariants."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="hypothesis not installed "
                    "(pip install repro[test]); protocol invariants skipped")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import protocol, simulate, sm_wt_halcone
from repro.core.engine import FENCE, NOP, READ, WRITE


def small_cfg():
    return sm_wt_halcone(n_gpus=2, cus_per_gpu=2, l1_sets=4, l2_sets=8,
                         tsu_sets=16)


op_strat = st.tuples(st.sampled_from([NOP, READ, WRITE]),
                     st.integers(min_value=0, max_value=31))


@settings(max_examples=25, deadline=None)
@given(st.lists(st.lists(op_strat, min_size=4, max_size=16),
                min_size=4, max_size=4),
       st.integers(0, 3))
def test_random_traces_swmr_and_monotone(traces_py, fence_round):
    """For arbitrary traces: clocks are monotone, every read returns a version
    that existed at read time (never from the future), and the engine never
    produces out-of-range data."""
    cfg = small_cfg()
    T = max(len(s) for s in traces_py) + 1
    ops = np.zeros((4, T), np.int32)
    addrs = np.zeros((4, T), np.int32)
    for i, s in enumerate(traces_py):
        for t, (o, a) in enumerate(s):
            ops[i, t], addrs[i, t] = o, a
    ops[:, fence_round] = np.where(ops[:, fence_round] == NOP, FENCE,
                                   ops[:, fence_round])
    r = simulate(cfg, ops, addrs)
    log = np.asarray(r["read_log"])
    # total writes per address over the whole run
    total_writes = np.zeros(64, np.int64)
    for i in range(4):
        for t in range(T):
            if ops[i, t] == WRITE:
                total_writes[addrs[i, t]] += 1
    # cumulative writes per address *before or at* each round
    cum = np.zeros((T + 1, 64), np.int64)
    for t in range(T):
        cum[t + 1] = cum[t]
        for i in range(4):
            if ops[i, t] == WRITE:
                cum[t + 1, addrs[i, t]] += 1
    for i in range(4):
        for t in range(T):
            if ops[i, t] == READ:
                v = log[i, t]
                assert 0 <= v <= cum[t + 1, addrs[i, t]], (
                    f"cu{i} round {t}: version {v} from the future "
                    f"(only {cum[t+1, addrs[i, t]]} writes so far)")
    st_ = r["state"]
    assert (np.asarray(st_.l1_cts) >= 0).all()
    assert (np.asarray(st_.l2_cts) >= 0).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 30), st.integers(0, 3), st.integers(0, 3))
def test_drf_visibility(n_pre_reads, writer, reader):
    """write -> fence -> read ALWAYS sees the write (any lease history)."""
    cfg = small_cfg()
    T = n_pre_reads + 3
    ops = np.zeros((4, T), np.int32)
    addrs = np.full((4, T), 3, np.int32)
    ops[reader, :n_pre_reads] = READ          # stretch the lease arbitrarily
    ops[writer, n_pre_reads] = WRITE
    ops[:, n_pre_reads + 1] = FENCE
    ops[reader, n_pre_reads + 2] = READ
    r = simulate(cfg, ops, addrs)
    assert np.asarray(r["read_log"])[reader, -1] == 1


@settings(max_examples=50, deadline=None)
@given(st.integers(0, protocol.TS_MAX), st.integers(1, 100),
       st.integers(1, 100))
def test_lease_math_pure(memts, rd, wr):
    """Write leases start strictly after every read admitted before them."""
    r_lease, memts_r = protocol.mm_read(np.int64(memts), rd)
    w_lease, memts_w = protocol.mm_write(np.int64(memts), wr)
    assert w_lease.wts == memts + 1 > memts          # strict ordering
    assert r_lease.rts == memts_r
    assert w_lease.rts == memts_w
    inst = protocol.install(np.int64(5), w_lease.wts, w_lease.rts)
    assert inst.rts > inst.wts - 1                    # non-degenerate lease
    assert protocol.cts_after_write(np.int64(5), inst.wts) >= 5


def test_timestamp_overflow_reinit():
    """16-bit overflow re-initializes instead of flushing; data stays correct
    because of write-through (one extra MM access, §3.2.6)."""
    cfg = small_cfg()
    cfg = type(cfg)(**{**cfg.__dict__, "rd_lease": 30000, "wr_lease": 29000})
    ops = np.zeros((4, 10), np.int32)
    addrs = np.full((4, 10), 2, np.int32)
    ops[0, :6] = [WRITE, WRITE, WRITE, READ, WRITE, READ]  # memts: 29k..116k
    r = simulate(cfg, ops, addrs)
    log = np.asarray(r["read_log"][0])
    assert log[3] == 3                                # pre-overflow correct
    assert log[5] == 4                                # post-overflow correct
    memts = np.asarray(r["state"].tsu_memts)
    assert memts.max() <= protocol.TS_MAX + 1


def test_tsu_eviction_lowest_memts():
    """When a TSU set fills, the entry with lowest memts is evicted and the
    evicted block's next access is a compulsory MM miss (still correct)."""
    cfg = sm_wt_halcone(n_gpus=2, cus_per_gpu=2, tsu_sets=1, tsu_ways=2,
                        l1_sets=4, l2_sets=8)
    ops = np.zeros((4, 8), np.int32)
    addrs = np.zeros((4, 8), np.int32)
    # 3 addresses through a 2-way TSU set
    for t, a in enumerate([1, 2, 3, 1]):
        ops[0, t] = READ
        addrs[0, t] = a
    r = simulate(cfg, ops, addrs)
    assert (np.asarray(r["read_log"][0, :4]) == 0).all()
    tags = np.asarray(r["state"].tsu_tag[:, :, :2])
    assert (tags >= 0).sum() <= 2 * cfg.n_hbm
