"""The backend-parity contract: one lease API, two engines.

``FabricBackend`` is the single front door every consumer (serving KV
adapter, training lease clock, runtime server/trainer, benchmarks) talks
to.  Two implementations exist and MUST be bit-identical on any op trace
(DESIGN.md §7; tests/test_fabric_parity.py):

  * ``HostFabric``   (this file)  — the host-object fabric (``TSUShard``
    dicts, ``_SetAssoc`` lists): slow, obvious, the differential-test
    ORACLE.  One Python call per key.
  * ``ArrayFabric``  (arrays.py)  — the array-native fabric: the whole
    state as ``core.state`` pytrees on device, a batch of ops applied as
    one jitted ``lax.scan``.  The production hot path.

Op vocabulary (exactly the host objects' public surface):

  read(key, replica)          ReplicaCache.get       -> (value, version)|None
  write(key, value, replica)  ReplicaCache.put       posted write-through
  fence()                     TSUFabric.barrier      drain + clock jump
  mm_write(key, value)        TSUFabric.write        raw authority write
  publish(key, value, node)   AuthoritativeStore.write = mm_write + adopt
  mm_read(key)                TSUFabric.read         raw authority read

Every backend also exposes ``grant_log`` — the ordered list of
``(key, wts, rts, version)`` leases the MM+TSU authority actually granted —
which is what the parity suite pins bit-for-bit.
"""
from __future__ import annotations

import abc
import collections
import dataclasses
from typing import Any, Dict, List, NamedTuple, Optional, Sequence, Tuple

from repro.coherence.fabric.cache import ReplicaCache, SharedCache
from repro.coherence.fabric.tsu import FabricConfig, TSUFabric

# A bounded TSU is part of the contract: the array backend is a fixed
# [n_shards, capacity] table, so the oracle must run with the same bound.
DEFAULT_TSU_CAPACITY = 1024
# grant_log bound, shared by BOTH backends so parity-compared logs
# truncate identically (differential traces are far shorter than this)
GRANT_LOG_LEN = 65536


class Op(NamedTuple):
    """One fabric operation, the unit of the differential trace."""

    kind: str                       # read|write|fence|mm_write|publish|mm_read
    key: Any = None
    value: Any = None
    replica: int = 0
    node: int = 0                   # publish target tier
    wr_lease: Optional[int] = None


def _bounded(cfg: FabricConfig) -> FabricConfig:
    if cfg.tsu_capacity is None:
        cfg = dataclasses.replace(cfg, tsu_capacity=DEFAULT_TSU_CAPACITY)
    return cfg


class ReadBatchHandle:
    """The pending result of ``FabricBackend.read_batch_async``: the
    device work is already dispatched; ``.result()`` runs (and caches)
    the host-side decode.  Single-threaded by design — JAX's async
    dispatch provides the overlap, the handle only defers the Python
    decode loop."""

    __slots__ = ("_finish", "_out")

    def __init__(self, finish):
        self._finish = finish
        self._out = None

    def result(self) -> List:
        if self._finish is not None:
            self._out = self._finish()
            self._finish = None
        return self._out


class FabricBackend(abc.ABC):
    """Common surface of the host-object and array-native fabrics."""

    cfg: FabricConfig
    n_nodes: int
    n_replicas: int
    grant_log: List[Tuple[Any, int, int, int]]

    # ------------------------------------------------------------ scalar
    @abc.abstractmethod
    def read(self, key, replica: int = 0) -> Optional[Tuple[Any, Optional[int]]]:
        ...

    @abc.abstractmethod
    def write(self, key, value, replica: int = 0,
              wr_lease: Optional[int] = None) -> None:
        ...

    @abc.abstractmethod
    def fence(self) -> int:
        ...

    @abc.abstractmethod
    def mm_write(self, key, value,
                 wr_lease: Optional[int] = None) -> Tuple[int, int, int]:
        """Raw authority write -> (wts, rts, version)."""

    @abc.abstractmethod
    def publish(self, key, value, node: int = 0,
                wr_lease: Optional[int] = None) -> Tuple[int, int]:
        """Authority write + adopt into ``node``'s shared tier -> (wts, rts)."""

    @abc.abstractmethod
    def mm_read(self, key) -> Optional[Tuple[Any, int, int, int]]:
        """Raw authority read -> (value, version, wts, rts) | None."""

    @abc.abstractmethod
    def memts(self, key) -> int:
        ...

    @abc.abstractmethod
    def stats(self) -> Dict[str, int]:
        ...

    @abc.abstractmethod
    def replica_stats(self, replica: int = 0) -> Dict[str, int]:
        ...

    @abc.abstractmethod
    def peek(self, key, replica: int = 0) -> bool:
        """Non-mutating: True iff a read would hit the replica tier."""

    # ------------------------------------------------------------ batched
    def read_batch(self, keys: Sequence, replica: int = 0) -> List:
        """Batched read with TWO-PHASE semantics (the serving hot path):
        replica-tier lease hits are served first, in op order, then the
        misses run the full descend-and-fill transition, in op order.
        Both backends implement exactly this order — the array backend
        serves phase 1 as ONE vectorized probe and, under the default
        ``pipeline="batched"``, the whole miss subset as a second
        vectorized pass (one batched TSU grant + one batched fill per
        tier, DESIGN.md §9) — so batched reads stay bit-identical across
        backends; ``apply`` keeps plain sequential per-op semantics.

        A batch every key of which hits phase 1 bumps the
        ``fast_read_batches`` stats field on every backend (part of the
        FabricStats block, so stats-equality assertions cover it)."""
        hits = [self.peek(k, replica) for k in keys]
        if keys and all(hits):
            self._note_fast_read_batch()
        out: List = [None] * len(keys)
        for i, k in enumerate(keys):
            if hits[i]:
                out[i] = self.read(k, replica)
        for i, k in enumerate(keys):
            if not hits[i]:
                out[i] = self.read(k, replica)
        return out

    def _note_fast_read_batch(self) -> None:
        """Record an all-hit batch in this backend's stats block."""

    def read_batch_async(self, keys: Sequence,
                         replica: int = 0) -> "ReadBatchHandle":
        """Dispatch a batched read and return a handle; ``.result()``
        yields exactly ``read_batch``'s output.  The array backend
        overrides this to dispatch the device work (phase-1 probe, miss
        pass, and — on the sharded engine — the next grant exchange)
        eagerly while deferring the host-side payload decode to
        ``.result()``, so a serving loop can overlap batch N's decode
        with batch N+1's dispatch (``Server.serve_stream``).  Ordering
        contract: resolve handles in dispatch order, and resolve every
        outstanding handle before the next write/fence — the deferred
        decode reads the payload maps those ops mutate.  This base
        implementation simply completes synchronously."""
        out = self.read_batch(keys, replica)
        return ReadBatchHandle(lambda: out)

    def write_batch(self, items: Sequence[Tuple[Any, Any]],
                    replica: int = 0, wr_lease: Optional[int] = None) -> None:
        """Batched posted writes: ONE batch boundary (a single ``apply``
        call — never a per-item loop), so backends that batch the write
        path (``ArrayFabric``'s vectorized write pass, DESIGN.md §11) see
        the whole storm at once.  Every non-empty batch bumps the
        ``write_batches`` stats field on every backend, mirroring
        ``fast_read_batches``, so host/array stats-equality assertions
        cover the write path's batch boundary too."""
        items = list(items)
        if not items:
            return
        self._note_write_batch()
        self.apply([Op("write", k, v, replica=replica, wr_lease=wr_lease)
                    for k, v in items])

    def _note_write_batch(self) -> None:
        """Record a posted-write batch in this backend's stats block."""

    def apply(self, ops: Sequence[Op]) -> List[Tuple[Op, Any]]:
        """Run an op trace; returns [(op, result)] in order.  The base
        implementation loops scalar calls; ``ArrayFabric`` overrides it
        with one jitted scan per batch."""
        out = []
        for op in ops:
            if op.kind == "read":
                r = self.read(op.key, op.replica)
            elif op.kind == "write":
                r = self.write(op.key, op.value, op.replica, op.wr_lease)
            elif op.kind == "fence":
                r = self.fence()
            elif op.kind == "mm_write":
                r = self.mm_write(op.key, op.value, op.wr_lease)
            elif op.kind == "publish":
                r = self.publish(op.key, op.value, op.node, op.wr_lease)
            elif op.kind == "mm_read":
                r = self.mm_read(op.key)
            else:
                raise ValueError(f"unknown op kind {op.kind!r}")
            out.append((op, r))
        return out


class HostFabric(FabricBackend):
    """The host-object fabric behind the backend contract — the oracle.

    Wraps one ``TSUFabric`` + ``n_nodes`` shared tiers + ``n_nodes *
    replicas_per_node`` replica tiers (replica r lives on node
    ``r // replicas_per_node``), and records every authority grant in
    ``grant_log`` in execution order.
    """

    def __init__(self, cfg: FabricConfig = FabricConfig(),
                 n_nodes: int = 1, replicas_per_node: int = 1):
        self.cfg = _bounded(cfg)
        self.n_nodes = n_nodes
        self.n_replicas = n_nodes * replicas_per_node
        self.fabric = TSUFabric(self.cfg)
        self.nodes = [SharedCache(self.fabric, node_id=i)
                      for i in range(n_nodes)]
        self.replicas = [ReplicaCache(self.nodes[r // replicas_per_node])
                         for r in range(self.n_replicas)]
        self.grant_log = collections.deque(maxlen=GRANT_LOG_LEN)
        self._tap_grants()

    def _tap_grants(self) -> None:
        fab, log = self.fabric, self.grant_log
        orig_read, orig_write = fab.read, fab.write

        def read(key, home_shard=None):
            g = orig_read(key, home_shard=home_shard)
            if g is not None:
                log.append((key, g.wts, g.rts, g.version))
            return g

        def write(key, value, *, wr_lease=None, home_shard=None):
            g = orig_write(key, value, wr_lease=wr_lease,
                           home_shard=home_shard)
            log.append((key, g.wts, g.rts, g.version))
            return g

        fab.read, fab.write = read, write

    # ------------------------------------------------------------- ops
    def _note_fast_read_batch(self) -> None:
        self.fabric.stats.bump("fast_read_batches")

    def _note_write_batch(self) -> None:
        self.fabric.stats.bump("write_batches")

    def peek(self, key, replica: int = 0) -> bool:
        return self.replicas[replica].peek(key)

    def read(self, key, replica: int = 0):
        return self.replicas[replica].get(key)

    def write(self, key, value, replica: int = 0, wr_lease=None) -> None:
        self.replicas[replica].put(key, value, wr_lease=wr_lease)

    def fence(self) -> int:
        return self.fabric.barrier()

    def mm_write(self, key, value, wr_lease=None):
        g = self.fabric.write(key, value, wr_lease=wr_lease)
        return g.wts, g.rts, g.version

    def publish(self, key, value, node: int = 0, wr_lease=None):
        g = self.fabric.write(key, value, wr_lease=wr_lease)
        self.nodes[node].adopt(key, value, g)
        return g.wts, g.rts

    def mm_read(self, key):
        g = self.fabric.read(key)
        if g is None:
            return None
        return g.value, g.version, g.wts, g.rts

    # ------------------------------------------------------------ views
    def memts(self, key) -> int:
        return self.fabric.memts(key)

    def stats(self) -> Dict[str, int]:
        return self.fabric.stats.to_dict()

    def replica_stats(self, replica: int = 0) -> Dict[str, int]:
        return self.replicas[replica].stats.to_dict()
