"""Architecture registry: the 10 assigned archs (exact public configs) plus
reduced smoke variants for CPU tests. Full configs are only ever instantiated
abstractly (ShapeDtypeStruct) via the dry-run."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig, Policy

from repro.configs import (  # noqa: E402
    mamba2_130m, qwen1_5_110b, smollm_360m, qwen2_5_14b, gemma3_4b,
    llava_next_34b, llama4_maverick, deepseek_v2, zamba2_1_2b, hubert_xlarge,
)

ARCHS = {
    "mamba2-130m": mamba2_130m.CONFIG,
    "qwen1.5-110b": qwen1_5_110b.CONFIG,
    "smollm-360m": smollm_360m.CONFIG,
    "qwen2.5-14b": qwen2_5_14b.CONFIG,
    "gemma3-4b": gemma3_4b.CONFIG,
    "llava-next-34b": llava_next_34b.CONFIG,
    "llama4-maverick-400b-a17b": llama4_maverick.CONFIG,
    "deepseek-v2-236b": deepseek_v2.CONFIG,
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
    "hubert-xlarge": hubert_xlarge.CONFIG,
}


def get(name: str) -> ModelConfig:
    return ARCHS[name]


def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """Same family/pattern, tiny dims — runs one train/forward step on CPU."""
    if cfg.global_every:
        n_layers = cfg.global_every + 1
    elif cfg.attn_every:
        n_layers = cfg.attn_every + 2
    elif cfg.first_dense:
        n_layers = cfg.first_dense + 4
    elif cfg.moe_every > 1:
        n_layers = 2 * cfg.moe_every
    else:
        n_layers = 3
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv, 4 - (4 % max(1, kv)))
    kw = dict(
        n_layers=n_layers, d_model=64, n_heads=heads, n_kv_heads=kv,
        d_head=16, d_ff=0 if cfg.d_ff == 0 else 128, vocab=256,
        attn_chunk=32, ssd_chunk=16,
        policy=Policy(moment_dtype=cfg.policy.moment_dtype),
    )
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=min(cfg.top_k, 2),
                  d_ff_expert=64,
                  n_shared_experts=min(cfg.n_shared_experts, 1))
    if cfg.is_mla:
        kw.update(q_lora=48, kv_lora=32, nope_head_dim=16, rope_head_dim=8,
                  v_head_dim=16)
    if cfg.family in ("ssm", "hybrid"):
        kw.update(ssm_state=16, ssm_head_dim=16, expand=2)
        if cfg.ssm_heads:
            kw.update(ssm_heads=8)
    if cfg.window:
        kw.update(window=16)
    if cfg.frontend == "audio":
        kw.update(d_frontend=32)
    if cfg.frontend == "vision":
        kw.update(n_patch_tokens=8)
    return dataclasses.replace(cfg, name=cfg.name + "-smoke", **kw)


SMOKE = {k: reduce_for_smoke(v) for k, v in ARCHS.items()}
