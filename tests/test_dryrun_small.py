"""Dry-run machinery regression: lower+compile+analyze a small arch on an
8-device placeholder mesh (subprocess: the XLA device flag must precede jax
init).  Covers mesh building, sharding rules, step builders, HLO analyzer."""
import json
import subprocess
import sys

SCRIPT = r'''
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")
import jax, json
from repro import configs as cfgs
from repro.launch import steps as S
from repro.launch import hloanalysis as H
from repro.models.config import SHAPES
mesh = jax.make_mesh((4, 2), ("data", "model"))
for arch, si in (("smollm-360m", 0), ("mamba2-130m", 3)):
    cfg = cfgs.get(arch)
    cell = SHAPES[si]
    fn, args, insh, outsh, don = S.build_cell(cfg, cell, mesh)
    compiled = jax.jit(fn, in_shardings=insh, out_shardings=outsh,
                       donate_argnums=don).lower(*args).compile()
    c = H.analyze(compiled.as_text(), 8)
    assert c.flops > 0, (arch, "no flops found")
    assert c.hbm_bytes > 0
    assert c.trips, "scan trip counts missing"
    print(json.dumps({"arch": arch, "flops": c.flops,
                      "trips": max(c.trips.values())}))
print("DRYRUN_SMALL_OK")
'''


def test_dryrun_small_mesh():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=560, cwd=".")
    assert "DRYRUN_SMALL_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
    rows = [json.loads(l) for l in r.stdout.splitlines() if l.startswith("{")]
    smollm = next(x for x in rows if x["arch"] == "smollm-360m")
    # layer-scan trip count must be visible to the analyzer (32 layers)
    assert smollm["trips"] >= 32
    # flops must be in the analytic ballpark: ~8*N*D/8dev for fwd+bwd+remat
    assert 1e13 < smollm["flops"] < 5e15
