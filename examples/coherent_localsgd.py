"""Lease-synchronized local SGD (HALCONE's write-lease applied to DP
training): wr_lease=4 cuts parameter-sync bytes ~4x at equal-ish loss.

    PYTHONPATH=src python examples/coherent_localsgd.py
"""
import jax
import numpy as np

from repro import configs as cfgs
from repro.coherence.lease_sync import LeaseConfig, VmappedWorkers
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw


def run(wr_lease, steps=16):
    cfg = cfgs.SMOKE["smollm-360m"]
    data = SyntheticLM(cfg, DataConfig(global_batch=2, seq_len=64))
    w = VmappedWorkers(cfg, adamw.AdamWConfig(lr=1e-3, warmup_steps=2),
                       LeaseConfig(wr_lease=wr_lease), n_workers=2,
                       key=jax.random.PRNGKey(0))
    loss = None
    for s in range(steps):
        b = data.batch(s)["tokens"]
        loss = w.step({"tokens": np.stack([b[0:1], b[1:2]])})
    return loss, w.collective_bytes, w.clock.memts


def main():
    l1, b1, _ = run(wr_lease=1)
    l4, b4, ts = run(wr_lease=4)
    print(f"sync DP (W=1):    final loss {l1:.3f}, sync bytes {b1:,}")
    print(f"lease  (W=4):     final loss {l4:.3f}, sync bytes {b4:,} "
          f"({b1/max(b4,1):.1f}x fewer), Lamport memts={ts}")
    assert b4 * 3 < b1
    print("OK: write-lease cut parameter-sync traffic ~4x")


if __name__ == "__main__":
    main()
