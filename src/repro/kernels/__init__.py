from repro.kernels.ops import (decode_attention, flash_attention,  # noqa: F401
                               lease_probe, miss_round, rmsnorm, ssd_chunk,
                               use_pallas, write_grant)
