"""Flash attention (forward) as a Pallas TPU kernel.

Online-softmax accumulation over KV blocks; grid (B, Hq, nq, nk) with the kv
dimension sequential ("arbitrary") and running (m, l, acc) in VMEM scratch.
GQA: the k/v index maps fold q-heads onto their kv head (h // q_per_kv).
Supports causal and sliding-window masking.  Validated in interpret mode
against ref.attention_ref; on TPU this keeps the [bq, Sk] score tile in VMEM
(never materialized to HBM) — the memory-roofline fix for train/prefill.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, bq, bk, nk):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # [bq, D]
    k = k_ref[0, 0].astype(jnp.float32)              # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)              # [bk, D]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale

    qpos = iq * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.zeros_like(s)
    if causal:
        mask = jnp.where(kpos > qpos, NEG_INF, mask)
    if window:
        mask = jnp.where(qpos - kpos >= window, NEG_INF, mask)
    s = s + mask

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, bq=128, bk=128,
                    interpret=True):
    """q: [B, Sq, Hq, D]; k, v: [B, Sk, Hkv, D] -> [B, Sq, Hq, D]."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    qpk = Hq // Hkv
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    nq, nk = Sq // bq, Sk // bk
    scale = D ** -0.5
    qt = q.transpose(0, 2, 1, 3)                     # [B, Hq, Sq, D]
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, qpk=qpk: (b, h // qpk, j, 0)),
            pl.BlockSpec((1, 1, bk, D),
                         lambda b, h, i, j, qpk=qpk: (b, h // qpk, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, D), q.dtype),
        scratch_shapes=[
            _vmem((bq, D), jnp.float32),
            _vmem((bq,), jnp.float32),
            _vmem((bq,), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
