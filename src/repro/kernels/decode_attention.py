"""Flash-decode: single-token attention over a long KV cache, as a Pallas
kernel.  Grid (B, Hq, nk) with sequential accumulation over KV blocks and
kv_len masking (cache fill level) — the serve_step hot loop for decode_32k /
long_500k.  On TPU the KV cache streams HBM->VMEM once; scores never leave
VMEM."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(kvlen_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref,
                   l_ref, *, scale, bk, nk):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                # [1, D]
    k = k_ref[0, 0].astype(jnp.float32)                # [bk, D]
    v = v_ref[0, 0].astype(jnp.float32)
    s = (q @ k.T) * scale                              # [1, bk]
    kpos = ik * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
    s = jnp.where(kpos >= kvlen_ref[0], NEG_INF, s)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + p @ v
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bk", "interpret"))
def decode_attention(q, k, v, kv_len, *, bk=512, interpret=True):
    """q: [B,1,Hq,D]; k,v: [B,Sk,Hkv,D]; kv_len: scalar int32."""
    B, Sq, Hq, D = q.shape
    assert Sq == 1
    Sk, Hkv = k.shape[1], k.shape[2]
    qpk = Hq // Hkv
    bk = min(bk, Sk)
    assert Sk % bk == 0
    nk = Sk // bk
    qt = q.transpose(0, 2, 1, 3)                       # [B, Hq, 1, D]
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    kernel = functools.partial(_decode_kernel, scale=D ** -0.5, bk=bk, nk=nk)
    from jax.experimental.pallas import tpu as pltpu
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(B, Hq, nk),
            in_specs=[
                pl.BlockSpec((1, 1, 1, D), lambda b, h, j, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, bk, D),
                             lambda b, h, j, *_, qpk=qpk: (b, h // qpk, j, 0)),
                pl.BlockSpec((1, 1, bk, D),
                             lambda b, h, j, *_, qpk=qpk: (b, h // qpk, j, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, 1, D), lambda b, h, j, *_: (b, h, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((1, D), jnp.float32),
                pltpu.VMEM((1,), jnp.float32),
                pltpu.VMEM((1,), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, Hq, 1, D), q.dtype),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32).reshape(1), qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
