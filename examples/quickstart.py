"""Quickstart: train a reduced smollm on synthetic data for 30 steps on CPU.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro import configs as cfgs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    cfg = cfgs.SMOKE["smollm-360m"]
    mesh = make_host_mesh()
    data = SyntheticLM(cfg, DataConfig(global_batch=4, seq_len=64))
    trainer = Trainer(cfg, mesh,
                      tcfg=TrainerConfig(total_steps=30, ckpt_period=10,
                                         ckpt_dir="/tmp/repro_quickstart"),
                      data=data)
    out = trainer.run()
    first, last = out["losses"][0], out["losses"][-1]
    print(f"step 0 loss={first:.3f}  ->  step {out['final_step']} "
          f"loss={last:.3f} (events: {out['events']})")
    assert last < first, "loss should decrease on the synthetic stream"
    print("OK: loss decreased; checkpoints in /tmp/repro_quickstart")


if __name__ == "__main__":
    main()
