"""Write-through checkpointing with restore-time resharding.

HALCONE's WT policy is what makes its timestamp overflow safe (MM always has
the data); this manager plays the MM role for the trainer: every `period`
steps the full sharded state is written through to durable storage, so any
worker ("cache") can be lost and refilled.  Restore accepts a DIFFERENT mesh
than the one that saved (elastic scaling): arrays are re-device_put under the
new shardings.
"""
from __future__ import annotations

import json
import pathlib
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree, prefix=""):
    if isinstance(tree, dict):
        for k in sorted(tree):
            yield from _flatten(tree[k], f"{prefix}/{k}")
    elif hasattr(tree, "_fields"):                    # NamedTuple
        for k in tree._fields:
            yield from _flatten(getattr(tree, k), f"{prefix}/{k}")
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _flatten(v, f"{prefix}/{i}")
    else:
        yield prefix, tree


def _unflatten_into(template, flat: Dict[str, np.ndarray], prefix=""):
    if isinstance(template, dict):
        return {k: _unflatten_into(template[k], flat, f"{prefix}/{k}")
                for k in sorted(template)}
    if hasattr(template, "_fields"):
        return type(template)(*[
            _unflatten_into(getattr(template, k), flat, f"{prefix}/{k}")
            for k in template._fields])
    if isinstance(template, (list, tuple)):
        return type(template)(
            _unflatten_into(v, flat, f"{prefix}/{i}")
            for i, v in enumerate(template))
    return flat[prefix]


class CheckpointManager:
    def __init__(self, directory, keep: int = 3, async_write: bool = True):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_write = async_write
        self._pending: Optional[threading.Thread] = None

    # ------------------------------------------------------------- save
    def save(self, step: int, state: Any, extra: Optional[dict] = None):
        """Write-through: snapshot to host memory synchronously (cheap), then
        persist in a background thread (off the training critical path —
        HALCONE's TSU-parallel-to-DRAM placement, in spirit)."""
        flat = {p: np.asarray(v) for p, v in _flatten(state)}
        meta = {"step": int(step), "time": time.time(),
                "extra": extra or {},
                "leaves": {p: [list(v.shape), str(v.dtype)]
                           for p, v in flat.items()}}
        self.wait()

        def _write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            tmp.mkdir(parents=True, exist_ok=True)
            np.savez(tmp / "state.npz",
                     **{p.replace("/", "|"): v for p, v in flat.items()})
            (tmp / "meta.json").write_text(json.dumps(meta))
            if final.exists():
                shutil.rmtree(final)
            tmp.rename(final)                       # atomic durability point
            self._gc()

        if self.async_write:
            self._pending = threading.Thread(target=_write, daemon=True)
            self._pending.start()
        else:
            _write()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        ckpts = sorted(self.dir.glob("step_*"))
        ckpts = [c for c in ckpts if c.is_dir() and not c.suffix == ".tmp"]
        for c in ckpts[:-self.keep]:
            shutil.rmtree(c, ignore_errors=True)

    # ---------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        self.wait()
        ckpts = sorted(self.dir.glob("step_*"))
        ckpts = [c for c in ckpts if c.is_dir()]
        if not ckpts:
            return None
        return int(ckpts[-1].name.split("_")[1])

    def restore(self, step: Optional[int], template: Any,
                shardings: Any = None) -> Any:
        """Rebuild `template`-structured state; device_put under `shardings`
        (which may target a different mesh than the writer's — elastic)."""
        self.wait()
        if step is None:
            step = self.latest_step()
        assert step is not None, "no checkpoint found"
        path = self.dir / f"step_{step:08d}"
        data = np.load(path / "state.npz")
        flat = {k.replace("|", "/"): data[k] for k in data.files}
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state
