"""Attention blocks: dense GQA (optional QKV bias, sliding window) and MLA
(DeepSeek-V2 multi-head latent attention with compressed KV cache)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, attention, rmsnorm, update_cache
from repro.models.params import P


# ---------------------------------------------------------------- dense GQA
def gqa_spec(cfg: ModelConfig) -> dict:
    D, Hq, Hkv, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    s = {
        "wq": P((D, Hq * Dh), ("embed", "heads")),
        "wk": P((D, Hkv * Dh), ("embed", "heads")),
        "wv": P((D, Hkv * Dh), ("embed", "heads")),
        "wo": P((Hq * Dh, D), ("heads", "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = P((Hq * Dh,), ("heads",), "zeros")
        s["bk"] = P((Hkv * Dh,), ("heads",), "zeros")
        s["bv"] = P((Hkv * Dh,), ("heads",), "zeros")
    return s


def gqa_apply(cfg: ModelConfig, p: dict, h, *, positions, cache=None, pos=None,
              window: int = 0, ctx=None):
    """h: [B, S, D].  Returns (out, new_cache)."""
    B, S, D = h.shape
    Hq, Hkv, Dh = cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    cd = h.dtype

    def proj(w, b):
        y = h @ p[w].astype(cd)
        if cfg.qkv_bias:
            y = y + p[b].astype(cd)
        return y

    q = proj("wq", "bq").reshape(B, S, Hq, Dh)
    k = proj("wk", "bk").reshape(B, S, Hkv, Dh)
    v = proj("wv", "bv")                                  # flat [B, S, Hkv*Dh]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta).reshape(B, S, Hkv * Dh)
    # NOTE §Perf: forcing an SP->TP head-shard boundary here was tried and
    # REFUTED for GQA (qwen110 wire 3.1e12 -> 1.8e13: Shardy already head-
    # shards dense GQA, the constraint only added seq re-gathers).  It is a
    # confirmed 2.5x win for MLA (below), where heads were left replicated.

    new_cache = None
    if cache is not None:
        start = pos if pos is not None else 0
        ck = update_cache(cache["k"], k, start)
        cv = update_cache(cache["v"], v, start)
        new_cache = {"k": ck, "v": cv}
    if pos is not None:                                   # decode: attend to cache
        kk = new_cache["k"].astype(cd).reshape(B, -1, Hkv, Dh)
        vv = new_cache["v"].astype(cd).reshape(B, -1, Hkv, Dh)
        out = attention(q, kk, vv, causal=False, window=window,
                        q_offset=0, kv_len=pos + S, chunk=cfg.attn_chunk)
    else:
        out = attention(q, k.reshape(B, S, Hkv, Dh), v.reshape(B, S, Hkv, Dh),
                        causal=cfg.causal, window=window, chunk=cfg.attn_chunk)
    return out.reshape(B, S, Hq * Dh) @ p["wo"].astype(cd), new_cache


def gqa_cache_spec(cfg: ModelConfig, batch: int, max_len: int, seq_axis: str):
    Hkv, Dh = cfg.n_kv_heads, cfg.d_head
    return {
        "k": P((batch, max_len, Hkv * Dh), ("batch", seq_axis, "heads"), "zeros"),
        "v": P((batch, max_len, Hkv * Dh), ("batch", seq_axis, "heads"), "zeros"),
    }


# ---------------------------------------------------------------------- MLA
def mla_spec(cfg: ModelConfig) -> dict:
    D, H = cfg.d_model, cfg.n_heads
    nope, rope_d, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    s = {
        "wkv_a": P((D, cfg.kv_lora + rope_d), ("embed", None)),
        "kv_ln": P((cfg.kv_lora,), (None,), "zeros"),
        "wk_b": P((cfg.kv_lora, H * nope), (None, "heads")),
        "wv_b": P((cfg.kv_lora, H * vd), (None, "heads")),
        "wo": P((H * vd, D), ("heads", "embed")),
    }
    if cfg.q_lora:
        s["wq_a"] = P((D, cfg.q_lora), ("embed", None))
        s["q_ln"] = P((cfg.q_lora,), (None,), "zeros")
        s["wq_b"] = P((cfg.q_lora, H * (nope + rope_d)), (None, "heads"))
    else:
        s["wq"] = P((D, H * (nope + rope_d)), ("embed", "heads"))
    return s


def mla_apply(cfg: ModelConfig, p: dict, h, *, positions, cache=None, pos=None,
              window: int = 0, ctx=None):
    B, S, D = h.shape
    H = cfg.n_heads
    nope, rope_d, vd = cfg.nope_head_dim, cfg.rope_head_dim, cfg.v_head_dim
    cd = h.dtype

    if cfg.q_lora:
        qa = rmsnorm(h @ p["wq_a"].astype(cd), p["q_ln"], cfg.rms_eps)
        q = (qa @ p["wq_b"].astype(cd)).reshape(B, S, H, nope + rope_d)
    else:
        q = (h @ p["wq"].astype(cd)).reshape(B, S, H, nope + rope_d)
    q_nope, q_rope = q[..., :nope], q[..., nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    if ctx is not None:
        q = ctx.constrain(q, "batch", None, "heads", None)
    kv = h @ p["wkv_a"].astype(cd)                        # [B,S,kv_lora+rope_d]
    latent = rmsnorm(kv[..., :cfg.kv_lora], p["kv_ln"], cfg.rms_eps)
    k_rope = apply_rope(kv[..., cfg.kv_lora:][..., None, :],
                        positions, cfg.rope_theta)[..., 0, :]
    ckv = jnp.concatenate([latent, k_rope], axis=-1)      # cached form

    new_cache = None
    if cache is not None:
        start = pos if pos is not None else 0
        new_cache = {"ckv": update_cache(cache["ckv"], ckv, start)}
    src = new_cache["ckv"].astype(cd) if pos is not None else ckv
    T = src.shape[1]
    lat, kr = src[..., :cfg.kv_lora], src[..., cfg.kv_lora:]
    scale = (nope + rope_d) ** -0.5

    if pos is not None and cfg.mla_absorb:
        # §Perf: DeepSeek's weight-absorption decode.  Instead of up-
        # projecting the WHOLE cache to per-head K/V (T*kv_lora*H*(nope+vd)
        # MACs per step!), fold W_uk into q and W_uv into the output, so
        # attention runs directly in the compressed latent space.
        wk_b = p["wk_b"].astype(cd).reshape(cfg.kv_lora, H, nope)
        q_lat = jnp.einsum("bshn,lhn->bshl", q[..., :nope], wk_b)  # [B,S,H,L]
        s_nope = jnp.einsum("bshl,btl->bhst", q_lat, lat)
        s_rope = jnp.einsum("bshr,btr->bhst", q[..., nope:], kr)
        s = (s_nope + s_rope).astype(jnp.float32) * scale
        kpos = jnp.arange(T)
        s = jnp.where(kpos[None, None, None, :] >= pos + S, -1e30, s)
        w = jax.nn.softmax(s, axis=-1).astype(cd)
        ctx_lat = jnp.einsum("bhst,btl->bshl", w, lat)             # [B,S,H,L]
        wv_b = p["wv_b"].astype(cd).reshape(cfg.kv_lora, H, vd)
        out = jnp.einsum("bshl,lhv->bshv", ctx_lat, wv_b)
        return out.reshape(B, S, H * vd) @ p["wo"].astype(cd), new_cache

    k_nope = (lat @ p["wk_b"].astype(cd)).reshape(B, T, H, nope)
    v = (lat @ p["wv_b"].astype(cd)).reshape(B, T, H, vd)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(kr[..., None, :],
                                                  (B, T, H, rope_d))], axis=-1)
    if ctx is not None:
        k = ctx.constrain(k, "batch", None, "heads", None)
        v = ctx.constrain(v, "batch", None, "heads", None)
    if pos is not None:
        out = attention(q, k, v, causal=False, window=window, kv_len=pos + S,
                        chunk=cfg.attn_chunk, softmax_scale=scale)
    else:
        out = attention(q, k, v, causal=cfg.causal, window=window,
                        chunk=cfg.attn_chunk, softmax_scale=scale)
    return out.reshape(B, S, H * vd) @ p["wo"].astype(cd), new_cache


def mla_cache_spec(cfg: ModelConfig, batch: int, max_len: int, seq_axis: str):
    return {"ckv": P((batch, max_len, cfg.kv_lora + cfg.rope_head_dim),
                     ("batch", seq_axis, "heads"), "zeros")}
