"""Parameter spec trees: one declaration drives real init (smoke tests),
abstract ShapeDtypeStruct stand-ins (dry-run), and NamedShardings (pjit)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.sharding import named_sharding


@dataclasses.dataclass(frozen=True)
class P:
    """One parameter leaf: shape + logical axes + init rule."""
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones | fan_in | a_log
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_leaf(x):
    return isinstance(x, P)


def tree_paths(spec, prefix=""):
    if _is_leaf(spec):
        yield prefix, spec
        return
    for k in sorted(spec):
        yield from tree_paths(spec[k], f"{prefix}/{k}")


def materialize(spec, key, dtype=jnp.float32):
    """Real arrays (used only for reduced smoke configs & examples)."""
    def leaf(path: str, p: P):
        k = jax.random.fold_in(key, np.uint32(abs(hash(path)) % (2**31)))
        if p.init == "zeros":
            return jnp.zeros(p.shape, dtype)
        if p.init == "ones":
            return jnp.ones(p.shape, dtype)
        if p.init == "a_log":   # mamba2 A in (-1, 0): A = -exp(A_log)
            return jnp.log(jax.random.uniform(k, p.shape, dtype, 1.0, 16.0))
        if p.init == "fan_in":
            fan = p.shape[0] if len(p.shape) > 1 else 1
            return (jax.random.normal(k, p.shape, dtype) / np.sqrt(max(1, fan)))
        return jax.random.normal(k, p.shape, dtype) * p.scale

    return _map_with_path(spec, leaf)


def abstract(spec, dtype=jnp.float32):
    """ShapeDtypeStruct stand-ins — no device allocation (dry-run path)."""
    return _map_with_path(spec, lambda _, p: jax.ShapeDtypeStruct(p.shape, dtype))


def shardings(spec, mesh, dtype=jnp.float32, rules=None):
    return _map_with_path(
        spec, lambda _, p: named_sharding(mesh, p.shape, p.axes, rules))


def pspecs(spec, mesh, rules=None):
    from repro.sharding import partition_spec
    return _map_with_path(
        spec, lambda _, p: partition_spec(mesh, p.shape, p.axes, rules))


def count_params(spec) -> int:
    return sum(int(np.prod(p.shape)) for _, p in tree_paths(spec))


def _map_with_path(spec, fn, prefix=""):
    if _is_leaf(spec):
        return fn(prefix, spec)
    return {k: _map_with_path(v, fn, f"{prefix}/{k}") for k, v in spec.items()}


def stack_specs(spec, n: int):
    """Prepend a scanned 'stack' dim of size n to every leaf in the subtree."""
    def leaf(_, p: P):
        return P((n,) + p.shape, ("stack",) + p.axes, p.init, p.scale)
    return _map_with_path(spec, leaf)
