"""Fused RMSNorm Pallas kernel: one HBM read + one write per row block
(XLA's unfused chain reads x three times)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(ms + eps)
    o_ref[...] = (y * (1.0 + w_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "br", "interpret"))
def rmsnorm(x, w, *, eps=1e-6, br=256, interpret=True):
    """x: [..., D]; w: [D]."""
    orig = x.shape
    D = orig[-1]
    R = 1
    for d in orig[:-1]:
        R *= d
    x2 = x.reshape(R, D)
    br = min(br, R)
    while R % br:
        br -= 1
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(R // br,),
        in_specs=[pl.BlockSpec((br, D), lambda i: (i, 0)),
                  pl.BlockSpec((D,), lambda i: (0,))],
        out_specs=pl.BlockSpec((br, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((R, D), x.dtype),
        interpret=interpret,
    )(x2, w)
    return out.reshape(orig)
