"""Latency histograms: log-bucketed shape, exact percentile summaries.

``LatencyHistogram`` records durations in seconds and serves two readers:

  * **log buckets** — geometric bucket boundaries (default 1µs · 2^k, 40
    buckets ≈ 1µs..10min) for cheap export/merge and long-horizon shape;
    the bucket layer is what a future per-tenant split aggregates over.
  * **exact percentiles** — samples are additionally retained (bounded by
    ``sample_cap``) so ``percentile(p)`` matches ``numpy.percentile``
    bit-for-bit up to the cap (pinned in tests/test_obs.py); past the cap
    it degrades to log-linear interpolation inside the bucket, which is
    the standard histogram-quantile estimate and is flagged by
    ``summary()["exact"] = False``.

Benchmark rows (BENCH_fabric.json) report ``p50/p95/p99`` from this class
instead of the old single median, so a latency tail — the thing an SLO
cares about — can no longer hide behind a good median.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Tuple

import numpy as np

__all__ = ["LatencyHistogram"]


class LatencyHistogram:
    """Log-bucketed latency histogram over seconds."""

    def __init__(self, base: float = 1e-6, growth: float = 2.0,
                 n_buckets: int = 40, sample_cap: int = 65536):
        if base <= 0 or growth <= 1 or n_buckets < 2:
            raise ValueError("need base > 0, growth > 1, n_buckets >= 2")
        self._bounds = base * growth ** np.arange(n_buckets, dtype=np.float64)
        self._counts = np.zeros(n_buckets + 1, np.int64)   # +1: overflow
        self._samples: List[float] = []
        self._cap = sample_cap
        self.count = 0
        self.sum_s = 0.0
        self.min_s = float("inf")
        self.max_s = 0.0

    # ------------------------------------------------------------- record
    def record(self, seconds: float) -> None:
        s = float(seconds)
        if s < 0:
            raise ValueError(f"negative latency {s}")
        self.count += 1
        self.sum_s += s
        self.min_s = min(self.min_s, s)
        self.max_s = max(self.max_s, s)
        self._counts[int(np.searchsorted(self._bounds, s, side="left"))] += 1
        if len(self._samples) < self._cap:
            self._samples.append(s)

    def record_many(self, seconds: Iterable[float]) -> "LatencyHistogram":
        for s in seconds:
            self.record(s)
        return self

    def merge(self, other: "LatencyHistogram") -> "LatencyHistogram":
        if not np.array_equal(self._bounds, other._bounds):
            raise ValueError("bucket layouts differ")
        self._counts += other._counts
        self.count += other.count
        self.sum_s += other.sum_s
        self.min_s = min(self.min_s, other.min_s)
        self.max_s = max(self.max_s, other.max_s)
        room = self._cap - len(self._samples)
        self._samples.extend(other._samples[:room])
        return self

    # ------------------------------------------------------------- views
    @property
    def exact(self) -> bool:
        """True while every recorded sample is retained — percentiles are
        then numpy-exact rather than bucket-interpolated."""
        return len(self._samples) == self.count

    def buckets(self) -> List[Tuple[float, int]]:
        """``[(le_seconds, cumulative_count)]`` rows, Prometheus-style;
        the final row is ``(inf, count)``."""
        cum = np.cumsum(self._counts)
        rows = [(float(b), int(c)) for b, c in zip(self._bounds, cum[:-1])]
        rows.append((float("inf"), int(cum[-1])))
        return rows

    def percentile(self, p: float) -> float:
        """p in [0, 100] -> seconds.  Exact (``numpy.percentile`` with the
        default linear interpolation) while samples are retained;
        log-linear within-bucket interpolation past the cap."""
        if self.count == 0:
            return 0.0
        if self.exact:
            return float(np.percentile(np.asarray(self._samples), p))
        # bucket-interpolated fallback: find the bucket holding rank r
        cum = np.cumsum(self._counts)
        r = (p / 100.0) * (self.count - 1)
        i = int(np.searchsorted(cum, r + 1, side="left"))
        i = min(i, len(self._bounds))
        lo = self._bounds[i - 1] if i > 0 else 0.0
        hi = self._bounds[i] if i < len(self._bounds) else self.max_s
        prev = cum[i - 1] if i > 0 else 0
        inside = max(int(self._counts[i]), 1)
        frac = (r + 1 - prev) / inside
        return float(lo + (hi - lo) * min(max(frac, 0.0), 1.0))

    def summary(self) -> Dict[str, float]:
        """The benchmark-row block: count, mean/p50/p95/p99/max in µs."""
        if self.count == 0:
            return {"count": 0, "mean_us": 0.0, "p50_us": 0.0,
                    "p95_us": 0.0, "p99_us": 0.0, "max_us": 0.0,
                    "exact": True}
        return {
            "count": self.count,
            "mean_us": round(self.sum_s / self.count * 1e6, 2),
            "p50_us": round(self.percentile(50) * 1e6, 2),
            "p95_us": round(self.percentile(95) * 1e6, 2),
            "p99_us": round(self.percentile(99) * 1e6, 2),
            "max_us": round(self.max_s * 1e6, 2),
            "exact": self.exact,
        }
