"""Backend-parity suite: the array-native fabric is BIT-IDENTICAL to the
host-object fabric (DESIGN.md §7).

Randomized op traces (reads/writes/fences/authority ops across replicas,
including forced 16-bit overflow reinits and TSU victim evictions) are
applied to both ``FabricBackend`` implementations; every observable must
match exactly: per-op results (values + versions), the ordered MM grant
log (wts/rts/version), the full FabricStats block, each replica's mirror
counters, and the per-key ``memts`` clocks.  A hypothesis layer fuzzes the
same property when hypothesis is installed (CI does; the ``[test]``
extra pulls it in).
"""
import numpy as np
import pytest

from repro.coherence.fabric import (ArrayFabric, FabricConfig, HostFabric,
                                    Op)
from repro.core import protocol

# one small geometry reused everywhere so the jitted op-scan compiles once
SMALL = dict(n_shards=2, rd_lease=8, wr_lease=4, tsu_capacity=4,
             shared_sets=4, shared_ways=2, replica_sets=2, replica_ways=2,
             max_in_flight=2)
# near-TS_MAX leases + a 2-entry TSU: every few ops trigger the 16-bit
# overflow reinit or a victim eviction
OVERFLOW = dict(n_shards=1, rd_lease=protocol.TS_MAX // 2, wr_lease=20000,
                tsu_capacity=2, shared_sets=2, shared_ways=1,
                replica_sets=1, replica_ways=2, max_in_flight=0)

KEYS = [f"k{i}" for i in range(8)]


def random_trace(rng, n_ops, n_replicas, wr_choices=(None,), n_nodes=2):
    ops = []
    for t in range(n_ops):
        r = int(rng.integers(n_replicas))
        k = KEYS[int(rng.integers(len(KEYS)))]
        c = rng.random()
        wl = wr_choices[int(rng.integers(len(wr_choices)))]
        if c < 0.45:
            ops.append(Op("read", k, replica=r))
        elif c < 0.8:
            ops.append(Op("write", k, f"v{t}", replica=r, wr_lease=wl))
        elif c < 0.85:
            ops.append(Op("fence"))
        elif c < 0.9:
            ops.append(Op("mm_write", k, f"m{t}", wr_lease=wl))
        elif c < 0.95:
            ops.append(Op("publish", k, f"p{t}",
                          node=int(rng.integers(n_nodes))))
        else:
            ops.append(Op("mm_read", k))
    return ops


def build_pair(cfg_kw, n_nodes=2, replicas_per_node=2):
    cfg = FabricConfig(**cfg_kw)
    return (HostFabric(cfg, n_nodes=n_nodes,
                       replicas_per_node=replicas_per_node),
            ArrayFabric(cfg, n_nodes=n_nodes,
                        replicas_per_node=replicas_per_node))


def assert_equivalent(host, arr, ops):
    hres = host.apply(ops)
    ares = arr.apply(ops)
    for i, ((op, hr), (_, ar)) in enumerate(zip(hres, ares)):
        assert hr == ar, f"op {i} ({op.kind} {op.key!r}): {hr!r} != {ar!r}"
    assert host.grant_log == arr.grant_log, "MM grant logs diverged"
    assert host.stats() == arr.stats(), "FabricStats diverged"
    for r in range(host.n_replicas):
        assert host.replica_stats(r) == arr.replica_stats(r), \
            f"replica {r} mirror counters diverged"
    for k in KEYS:
        assert host.memts(k) == arr.memts(k), f"memts({k!r}) diverged"


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_differential_random_trace(seed):
    host, arr = build_pair(SMALL)
    ops = random_trace(np.random.default_rng(seed), 350, 4)
    assert_equivalent(host, arr, ops)


@pytest.mark.parametrize("seed", [0, 1])
def test_differential_overflow_reinit_and_tsu_eviction(seed):
    """Forced 16-bit wraps + constant victim eviction in a 2-entry TSU."""
    host, arr = build_pair(OVERFLOW, n_nodes=1, replicas_per_node=2)
    ops = random_trace(np.random.default_rng(seed), 250, 2,
                       wr_choices=(None, 1, 30000), n_nodes=1)
    assert_equivalent(host, arr, ops)
    assert host.stats()["overflow_reinits"] > 0, "overflow never triggered"
    assert host.stats()["tsu_evictions"] > 0, "eviction never triggered"


def test_read_batch_two_phase_parity():
    """The batched read contract (hits vectorized first, misses in order)
    produces identical results, stats and mirrors on both backends."""
    host, arr = build_pair(SMALL)
    rng = np.random.default_rng(7)
    warm = random_trace(rng, 120, 4)
    host.apply(warm)
    arr.apply(warm)
    batch = [KEYS[int(rng.integers(len(KEYS)))] for _ in range(32)]
    batch.append("never-written")       # unknown key exercises phase 2
    assert host.read_batch(batch, replica=1) == arr.read_batch(batch,
                                                               replica=1)
    assert host.stats() == arr.stats()
    assert host.replica_stats(1) == arr.replica_stats(1)


def test_fast_path_equals_scan_path_on_all_hit_batch():
    """Phase 1 (one vectorized tier_probe) is bit-identical to the op-scan
    on an all-hit batch — results, counters, and the full device state."""
    import jax

    a1 = ArrayFabric(FabricConfig(**SMALL), n_nodes=1, replicas_per_node=1)
    a2 = ArrayFabric(FabricConfig(**SMALL), n_nodes=1, replicas_per_node=1)
    keys = KEYS[:4]
    for b in (a1, a2):
        for k in keys:
            b.write(k, f"{k}@0")
        b.fence()
    r1 = a1.read_batch(keys)                                  # fast path
    r2 = [x for _, x in a2.apply([Op("read", k) for k in keys])]
    assert r1 == r2
    assert a1.fast_read_batches == 1
    assert a1.stats() == a2.stats()
    for x, y in zip(jax.tree_util.tree_leaves(a1._af),
                    jax.tree_util.tree_leaves(a2._af)):
        assert (np.asarray(x) == np.asarray(y)).all()


def test_single_transition_layer():
    """Acceptance pin: both consumers import the rules from core.state."""
    from repro.coherence.fabric import arrays
    from repro.core import engine, state
    assert engine.S is state
    assert arrays.S is state


# ---------------------------------------------------------------- fuzzing
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # CI installs it via the [test] extra
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _op = st.one_of(
        st.tuples(st.just("read"), st.integers(0, 3),
                  st.sampled_from(KEYS)),
        st.tuples(st.just("write"), st.integers(0, 3),
                  st.sampled_from(KEYS)),
        st.tuples(st.just("fence"), st.just(0), st.just(KEYS[0])),
        st.tuples(st.just("mm_write"), st.just(0), st.sampled_from(KEYS)),
        st.tuples(st.just("publish"), st.integers(0, 1),
                  st.sampled_from(KEYS)),
        st.tuples(st.just("mm_read"), st.just(0), st.sampled_from(KEYS)),
    )

    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(_op, min_size=1, max_size=60))
    def test_hypothesis_differential(trace):
        host, arr = build_pair(SMALL)
        ops = []
        for t, (kind, idx, key) in enumerate(trace):
            if kind == "fence":
                ops.append(Op("fence"))
            elif kind == "publish":
                ops.append(Op("publish", key, f"p{t}", node=idx))
            elif kind in ("mm_write", "write"):
                ops.append(Op(kind, key, f"v{t}", replica=idx))
            else:
                ops.append(Op(kind, key, replica=idx))
        assert_equivalent(host, arr, ops)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_differential():
        pass
