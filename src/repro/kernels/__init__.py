from repro.kernels.ops import (decode_attention, flash_attention,  # noqa: F401
                               lease_probe, rmsnorm, ssd_chunk, use_pallas)
