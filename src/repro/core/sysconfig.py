"""System configurations for the paper's five evaluated MGPU systems (§4.1).

Geometry is Table 2's real sizes (64 B blocks): L1 16KB 4-way, L2 256KB
16-way x 8 banks/GPU, 8 HBM stacks, TSU 8-way.  Latency/bandwidth constants
follow §4.1: PCIe4 32 GB/s/dir links, 1 TB/s aggregate L2<->MM, 100-cycle MC,
50-cycle TSU (accessed in parallel with DRAM), 1 GHz clock.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    name: str = "SM-WT-C-HALCONE"
    n_gpus: int = 4
    cus_per_gpu: int = 32
    topology: str = "sm"            # sm | rdma
    l2_policy: str = "wt"           # wt | wb
    protocol: str = "halcone"       # none | halcone | hmg
    rd_lease: int = 10
    wr_lease: int = 5
    # geometry (64 B blocks)
    l1_sets: int = 64
    l1_ways: int = 4
    l2_banks: int = 8
    l2_sets: int = 256
    l2_ways: int = 16
    n_hbm: int = 8
    tsu_sets: int = 2048
    tsu_ways: int = 8
    page_blocks: int = 64           # 4 KB pages interleaved across modules
    # latencies (cycles @ 1 GHz)
    l1_lat: float = 4.0
    l2_lat: float = 28.0
    mm_lat: float = 200.0           # incl. the calibrated 100-cycle MC
    tsu_lat: float = 50.0           # parallel with DRAM -> off critical path
    pcie_lat: float = 600.0
    # per-64B-block service times (queuing): cycles/block
    l2_service: float = 6.0         # effective bank occupancy per access
    mm_service: float = 3.0         # row activation + 1TB/s aggregate
    pcie_service: float = 2.0       # 32 GB/s = 32 B/cycle -> 2 cyc/block
    mlp: float = 4.0                # per-CU memory-level parallelism: a CU's
                                    # wavefronts overlap ~4 outstanding misses

    @property
    def n_cus(self) -> int:
        return self.n_gpus * self.cus_per_gpu

    @property
    def coherent(self) -> bool:
        return self.protocol == "halcone"


def rdma_wb_nc(**kw) -> SystemConfig:
    return SystemConfig(name="RDMA-WB-NC", topology="rdma", l2_policy="wb",
                        protocol="none", **kw)


def rdma_wb_hmg(**kw) -> SystemConfig:
    return SystemConfig(name="RDMA-WB-C-HMG", topology="rdma", l2_policy="wb",
                        protocol="hmg", **kw)


def sm_wb_nc(**kw) -> SystemConfig:
    return SystemConfig(name="SM-WB-NC", topology="sm", l2_policy="wb",
                        protocol="none", **kw)


def sm_wt_nc(**kw) -> SystemConfig:
    return SystemConfig(name="SM-WT-NC", topology="sm", l2_policy="wt",
                        protocol="none", **kw)


def sm_wt_halcone(**kw) -> SystemConfig:
    return SystemConfig(name="SM-WT-C-HALCONE", topology="sm", l2_policy="wt",
                        protocol="halcone", **kw)


ALL_CONFIGS = (rdma_wb_nc, rdma_wb_hmg, sm_wb_nc, sm_wt_nc, sm_wt_halcone)
