"""Overlapped grant exchange: ``read_batch_async`` / ``serve_stream``
parity (ISSUE 8 tentpole, lever 1).

The sharded fabric double-buffers the packed TSU exchange: ``_xout``
re-dispatches the next gather right after scattering a batch's results,
and ``read_batch_async`` defers only the host-side payload decode — the
device work (probe, miss pass, next exchange) is in flight when the
handle returns.  None of that may change a single bit: these tests pin
the overlapped mode to the sync path and to ``HostFabric`` — results,
grant log, stats, replica mirrors and the full device state — on the
single-device fabric here and on the mesh-placed sharded fabric via the
forced-8-device subprocess harness (same idiom as
``test_fabric_parity``).  ``Server.serve_stream`` rides the same
boundary: wave N+1's probe dispatch overlaps wave N's decode, with
outputs equal to back-to-back ``serve`` calls.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.coherence.fabric import (ArrayFabric, FabricConfig, HostFabric,
                                    Op, ReadBatchHandle)

SMALL = dict(n_shards=2, rd_lease=8, wr_lease=4, tsu_capacity=16,
             shared_sets=4, shared_ways=2, replica_sets=2, replica_ways=2,
             max_in_flight=3)
KEYS = [f"k{i}" for i in range(12)]


def _drive(fab, seed, async_reads, n_calls=6):
    """One storm schedule, sync or overlapped: publish, then interleaved
    read batches / write batches / fences.  In async mode every read
    batch is dispatched via ``read_batch_async`` and resolved at the
    latest point the ordering contract allows (just before the next
    write/fence — i.e. after arbitrary host work has overlapped the
    in-flight device batch)."""
    rng = np.random.default_rng(seed)
    out = [fab.apply([Op("publish", k, f"{k}@0", node=i % 2)
                      for i, k in enumerate(KEYS)])]
    for c in range(n_calls):
        batch = [KEYS[int(i)] for i in rng.integers(0, len(KEYS), 20)]
        rep = int(rng.integers(4))
        if async_reads:
            handle = fab.read_batch_async(batch, replica=rep)
            assert isinstance(handle, ReadBatchHandle)
            _ = sum(i * i for i in range(200))   # overlapped host work
            out.append(("rb", handle.result()))
            assert handle.result() is handle.result()         # cached
        else:
            out.append(("rb", fab.read_batch(batch, replica=rep)))
        if c % 2:
            fab.write_batch([(KEYS[int(i)], f"w{c}.{i}")
                             for i in rng.integers(0, len(KEYS), 6)],
                            replica=rep)
        if c % 3 == 2:
            out.append(("fence", fab.fence()))
    return out


def _assert_same_fabric(a, b):
    assert list(a.grant_log) == list(b.grant_log)
    assert a.stats() == b.stats()
    for r in range(a.n_replicas):
        assert a.replica_stats(r) == b.replica_stats(r)
    for x, y in zip(jax.tree_util.tree_leaves(a._af),
                    jax.tree_util.tree_leaves(b._af)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_read_batch_async_matches_sync_and_host(seed):
    """Overlapped reads are bit-identical to sync reads and to the host
    oracle — results, grant log, stats, mirrors, device state."""
    cfg = FabricConfig(**SMALL)
    mk = lambda: ArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    a_sync, a_async = mk(), mk()
    host = HostFabric(cfg, n_nodes=2, replicas_per_node=2)
    out_async = _drive(a_async, seed, async_reads=True)
    out_sync = _drive(a_sync, seed, async_reads=False)
    out_host = _drive(host, seed, async_reads=False)
    assert out_async == out_sync == out_host
    assert list(a_async.grant_log) == list(host.grant_log)
    assert a_async.stats() == host.stats()
    _assert_same_fabric(a_async, a_sync)


def test_read_batch_async_all_hit_and_fallback_paths():
    """The handle contract holds on every internal path: all-hit batches
    (no miss pass), miss-heavy batches, and the op-scan fallback for
    storm shapes over the round budget."""
    cfg = FabricConfig(**SMALL)
    fab = ArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    host = HostFabric(cfg, n_nodes=2, replicas_per_node=2)
    for f in (fab, host):
        f.apply([Op("publish", k, f"{k}@0") for k in KEYS])
    # miss-heavy (first touch), then all-hit (immediate re-read), then a
    # deep conflict chain (one key repeated > round budget -> fallback)
    for batch in ([KEYS[i % 6] for i in range(12)],
                  [KEYS[i % 6] for i in range(12)],
                  [KEYS[0]] * 17 + KEYS[:3]):
        got = fab.read_batch_async(batch, replica=1).result()
        want = host.read_batch(batch, replica=1)
        assert got == want
    assert fab.stats() == host.stats()


def test_serve_stream_matches_sequential_serve():
    """``serve_stream`` (wave N+1's probe dispatched under wave N's
    decode) returns exactly what back-to-back ``serve`` calls return,
    with equal fabric/cache telemetry."""
    from repro import configs as cfgs
    from repro.models import init_model
    from repro.runtime.server import Request, Server

    cfg = cfgs.SMOKE["smollm-360m"]
    params = init_model(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(2, cfg.vocab, 16).astype(np.int32)
               for _ in range(3)]
    # identical prompt composition per wave: waves 1-2 re-probe wave 0's
    # group keys, so the cross-wave lease-hit path is exercised
    waves = [[Request(rid=w * 10 + i, prompt=prompts[i], max_new=3)
              for i in range(3)]
             for w in range(3)]

    srv_seq = Server(cfg, params, batch_size=2, max_len=64)
    out_seq = {}
    for wave in waves:
        out_seq.update(srv_seq.serve(wave))
    srv_str = Server(cfg, params, batch_size=2, max_len=64)
    out_str = srv_str.serve_stream(iter(waves))

    assert set(out_str) == set(out_seq)
    for rid in out_seq:
        np.testing.assert_array_equal(out_str[rid], out_seq[rid])
    assert srv_str.cache_stats == srv_seq.cache_stats
    assert srv_str.fabric_stats == srv_seq.fabric_stats
    # the stream actually exercised the lease path across waves
    assert srv_str.cache_stats["hits"] >= 1


def test_serve_stream_ragged_waves_match_sequential_serve():
    """The edge cases continuous batch formation feeds the stream path
    (ISSUE 9): empty waves, unequal/non-pow2 wave sizes, and a final
    partial wave — all bit-identical to sequential ``serve`` on outputs
    and on cache/fabric telemetry."""
    from repro import configs as cfgs
    from repro.models import init_model
    from repro.runtime.server import Request, Server

    cfg = cfgs.SMOKE["smollm-360m"]
    params = init_model(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(2, cfg.vocab, 16).astype(np.int32)
               for _ in range(4)]
    reqs = [Request(rid=i, prompt=prompts[i % 4], max_new=3)
            for i in range(9)]
    # ragged schedule: empty wave up front, a singleton, a non-pow2
    # 3-wave, an empty wave mid-stream, a full-ish 4-wave, and a final
    # partial — exactly the shapes deadline fires produce
    waves = [[], [reqs[0]], reqs[1:4], [], reqs[4:8], reqs[8:]]

    srv_seq = Server(cfg, params, batch_size=2, max_len=64)
    out_seq = {}
    for wave in waves:
        out_seq.update(srv_seq.serve(wave))
    srv_str = Server(cfg, params, batch_size=2, max_len=64)
    out_str = srv_str.serve_stream(iter(waves))

    assert set(out_str) == set(out_seq) == {r.rid for r in reqs}
    for rid in out_seq:
        np.testing.assert_array_equal(out_str[rid], out_seq[rid])
    assert srv_str.cache_stats == srv_seq.cache_stats
    assert srv_str.fabric_stats == srv_seq.fabric_stats


def test_serve_stream_takes_form_waves_output():
    """``scheduler.form_waves`` → ``serve_stream`` end-to-end: the
    arrival-driven waves (variable sizes incl. a final partial) serve
    every request once, with outputs equal to a fixed-wave serve of the
    same requests."""
    from repro import configs as cfgs
    from repro.models import init_model
    from repro.runtime.scheduler import BatchPolicy, form_waves
    from repro.runtime.server import Request, Server

    cfg = cfgs.SMOKE["smollm-360m"]
    params = init_model(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(11)
    prompts = [rng.integers(2, cfg.vocab, 16).astype(np.int32)
               for _ in range(3)]
    reqs = [Request(rid=i, prompt=prompts[i % 3], max_new=3)
            for i in range(7)]
    # trickle then burst: deadline singletons, then a full wave + partial
    t_arrive = [0.0, 0.1, 0.2, 0.30, 0.301, 0.302, 0.303]
    pol = BatchPolicy(mode="continuous", max_batch=3, max_wait_s=1e-3)
    waves = form_waves(t_arrive, reqs, pol)
    sizes = [len(w) for w in waves]
    assert sum(sizes) == 7 and max(sizes) <= 3 and min(sizes) == 1

    srv = Server(cfg, params, batch_size=2, max_len=64)
    out = srv.serve_stream(iter(waves))
    srv_ref = Server(cfg, params, batch_size=2, max_len=64)
    out_ref = {}
    for wave in [reqs[:3], reqs[3:6], reqs[6:]]:
        out_ref.update(srv_ref.serve(wave))
    assert set(out) == {r.rid for r in reqs}
    for rid in out:
        np.testing.assert_array_equal(out[rid], out_ref[rid])


def _overlap_multidevice_check():
    """Forced-8-device body: overlapped reads on the mesh-placed sharded
    fabric (double-buffered gather + deferred decode) stay bit-identical
    to the sync sharded path and the host oracle."""
    from repro.coherence.fabric import ShardedArrayFabric

    assert len(jax.devices()) >= 8, "needs the forced 8-device host mesh"
    cfg = FabricConfig(**dict(SMALL, n_shards=8))
    host = HostFabric(cfg, n_nodes=2, replicas_per_node=2)
    sh_sync = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    sh_async = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    assert sh_sync.n_shard_devices == 8
    out_async = _drive(sh_async, 5, async_reads=True)
    out_sync = _drive(sh_sync, 5, async_reads=False)
    out_host = _drive(host, 5, async_reads=False)
    assert out_async == out_sync == out_host
    assert list(sh_async.grant_log) == list(host.grant_log)
    assert sh_async.stats() == host.stats()
    assert sh_async.stats() == sh_sync.stats()
    for r in range(sh_async.n_replicas):
        assert sh_async.replica_stats(r) == sh_sync.replica_stats(r)
    assert sh_async.stats()["bytes_inter_gpu"] > 0     # real mesh hops
    return True


def test_overlap_parity_forced_8_devices():
    """Run ``_overlap_multidevice_check`` on an 8-device host mesh: in
    process if this session was launched with the forced flag (CI), else
    in a subprocess with
    XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    if len(jax.devices()) >= 8:
        assert _overlap_multidevice_check()
        return
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), os.path.join(repo, "tests")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-c",
         "from test_overlap_stream import _overlap_multidevice_check; "
         "assert _overlap_multidevice_check(); print('OVERLAP-PARITY-OK')"],
        env=env, cwd=repo, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"forced-8-device overlap subprocess failed:\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    assert "OVERLAP-PARITY-OK" in proc.stdout
