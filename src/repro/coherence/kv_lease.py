"""Lease-coherent prefix-KV cache for multi-replica serving.

The serving-side transfer of HALCONE (DESIGN.md §2b): prefill results (prefix
KV blocks) are shared across serving replicas.  The authoritative store plays
the MM+TSU; each replica's local cache holds blocks with (wts, rts) leases and
*self-invalidates* on expiry instead of receiving invalidation messages when a
prefix is recomputed/updated (e.g. after a model refresh or cache eviction
upstream).  Identical timestamp rules to repro.core.protocol.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

from repro.core import protocol


@dataclasses.dataclass
class _Entry:
    value: Any
    version: int
    memts: int = 0


class AuthoritativeStore:
    """The MM+TSU: versioned prefix blocks + memts per key."""

    def __init__(self, rd_lease: int = 8, wr_lease: int = 4):
        self.rd_lease = rd_lease
        self.wr_lease = wr_lease
        self.blocks: Dict[str, _Entry] = {}

    def write(self, key: str, value: Any) -> Tuple[int, int]:
        e = self.blocks.get(key)
        memts = e.memts if e else 0
        lease, new_memts = protocol.mm_write(memts, self.wr_lease)
        ver = (e.version + 1) if e else 1
        self.blocks[key] = _Entry(value, ver, new_memts)
        return int(lease.wts), int(lease.rts)

    def read(self, key: str) -> Optional[Tuple[Any, int, int, int]]:
        e = self.blocks.get(key)
        if e is None:
            return None
        lease, e.memts = protocol.mm_read(e.memts, self.rd_lease)
        return e.value, e.version, int(lease.wts), int(lease.rts)


class LeaseKVCache:
    """A serving replica's local cache with a logical clock.

    cts advances on every local admission of a new version (a 'write' in
    protocol terms: the replica observed new state).  Reads hit while
    cts <= rts; expiry triggers a refetch from the store — NO invalidation
    traffic ever flows between replicas.
    """

    def __init__(self, store: AuthoritativeStore, capacity: int = 128):
        self.store = store
        self.capacity = capacity
        self.cts = 0
        self.local: Dict[str, dict] = {}
        self.stats = {"hits": 0, "coherence_misses": 0, "compulsory": 0,
                      "refetches": 0, "capacity_evictions": 0}

    def get(self, key: str):
        ent = self.local.get(key)
        if ent is not None and protocol.valid(self.cts, ent["rts"]):
            self.stats["hits"] += 1
            return ent["value"], ent["version"]
        if ent is not None:
            self.stats["coherence_misses"] += 1
        else:
            self.stats["compulsory"] += 1
        got = self.store.read(key)
        if got is None:
            return None
        value, ver, wts, rts = got
        self.stats["refetches"] += 1
        lease = protocol.install(self.cts, wts, rts)
        self._install(key, value, ver, int(lease.wts), int(lease.rts))
        return value, ver

    def put(self, key: str, value: Any):
        """Local write-through: publish to the store, adopt its lease, and
        advance this replica's clock (cts = max(cts, wts))."""
        wts, rts = self.store.write(key, value)
        lease = protocol.install(self.cts, wts, rts)
        self.cts = int(protocol.cts_after_write(self.cts, lease.wts))
        ver = self.store.blocks[key].version
        self._install(key, value, ver, int(lease.wts), int(lease.rts))

    def _install(self, key, value, ver, wts, rts):
        if len(self.local) >= self.capacity and key not in self.local:
            victim = min(self.local, key=lambda k: self.local[k]["rts"])
            del self.local[victim]
            self.stats["capacity_evictions"] += 1
        self.local[key] = {"value": value, "version": ver,
                           "wts": wts, "rts": rts}
