"""The batched grant pipeline: vectorized miss / write / fence passes.

PR 3's two-phase batched read served every replica-tier lease hit with ONE
vectorized probe (phase 1) but re-ran the miss subset through the exact
per-op scan.  PR 5 completed the fast path (DESIGN.md §9): the whole miss
subset is served by a SECOND vectorized pass — one batched tier probe, one
batched TSU grant (``state.tsu_lease_batch``), one batched fill per tier —
and PR 6 added the posted-write twin.  This module now carries the ISSUE 8
tentpole: **graph-colored rounds** and a **lane-static write pass**, plus a
dedicated **fence pass**, so a set-colliding storm needs `max chain depth`
rounds instead of `number of contiguous conflict-free segments`.

Bit-identity with the sequential oracle (`HostFabric`, and the
``pipeline="scan"`` op-scan) is preserved by executing the pass over
**conflict-free rounds**:

  * ``conflict_rounds`` assigns each miss-subset op a round by
    order-preserving graph coloring: ops conflict when they share a key, a
    replica-tier set, or a shared-tier set, and within every such conflict
    chain round numbers strictly increase in op order (chain-depth
    first-fit, see ``color_rounds``).  Ops in one round touch disjoint
    cache state, hence executing them simultaneously equals executing
    them sequentially — and ops in *different* rounds that share state are
    executed in op order because their rounds are ordered.  The colored
    assignment never uses more rounds than the greedy contiguous splitter
    (``conflict_rounds_greedy``, kept as the property-test oracle).
  * The one piece of state every op shares — the per-store LRU tick — is
    reproduced exactly in two steps: inside the round scan each touch/fill
    writes a *provisional* tick (its execution-order rank, the §9
    prefix-sum math), and after the scan a permutation LUT remaps every
    provisional tick to the exact op-order value the sequential scan would
    have written.  Within any one set the events already execute in op
    order (same-set ops conflict, so they sit in ordered rounds), so every
    intermediate victim/probe decision is exact; only the absolute stored
    tick values need the final remap.  When rounds are contiguous the
    remap is the identity.

All rounds run inside ONE jitted ``lax.scan`` over the round masks (the
fabric state is the scan carry, so XLA updates it in place; per-op
results accumulate into one packed ``[7, M]`` buffer), and on the sharded
fabric the packed TSU buffer is assembled ONCE before the round scan —
the per-batch collective budget stays O(1) no matter how many rounds the
subset needs.

The write pass is **lane-static**: the bounded ring's drain schedule is a
pure function of op index (op j drains iff L0 + j + 1 > max_in_flight), so
``write_schedule`` resolves every drained entry on the host and hands the
pass a per-lane ``sched`` block — the ring scatter, head/len update and
LRU tick ranks all hoist out of the round scan, and the in-scan body keeps
only the state-dependent math (TSU commits, clock chains, tier installs,
counters).  ``make_fence_pass`` drains *all* node queues in node order with
the same machinery and ends with the §11b global-clock jump.

``make_miss_pass``/``make_write_pass``/``make_fence_pass`` return pure
passes; `arrays.py` owns jitting and the mesh placement (packed-TSU
``owner_gather`` in, ``owner_take`` out).  ``collective_counts`` walks a
jaxpr and reports how many collectives it contains and how many sit inside
a scan/while loop — the parity suite's O(1)-collectives-per-batch pin and
the ``batched_grants`` benchmark row both read it.
"""
from __future__ import annotations

import collections
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.coherence.fabric.stats import GI, G_KEYS, RI, R_KEYS
from repro.core import state as S
from repro.kernels import ops as K
# the packed per-op result block ([7, M] int32) — the layout contract now
# lives in core.state so the simulator's round step emits the same record
# (re-exported here for existing consumers)
from repro.core.state import RES_FIELDS  # noqa: F401

_i32 = jnp.int32
_NEG = jnp.int32(-2 ** 30)


def _b2i(b):
    return b.astype(_i32)


def _gsum(**kw):
    out = jnp.zeros((len(G_KEYS),), _i32)
    return out.at[jnp.array([GI[k] for k in kw], _i32)].add(
        jnp.stack(list(kw.values())))


def _rsum(**kw):
    out = jnp.zeros((len(R_KEYS),), _i32)
    return out.at[jnp.array([RI[k] for k in kw], _i32)].add(
        jnp.stack(list(kw.values())))


# ------------------------------------------------------------ round coloring
def color_rounds(footprints: Sequence[Sequence]) -> List[int]:
    """Order-preserving chain-depth graph coloring.

    ``footprints[j]`` is the set of resources op *j* touches; two ops
    conflict iff their footprints intersect.  The classic interval-free
    relaxation: op *j*'s color is one more than the largest color among
    the **last** prior user of each of its resources —

        color(j) = max(0, max_{res in fp(j)} last[res] + 1)

    which is valid because colors strictly increase along every resource
    chain (so the *last* user of a resource carries the maximum color of
    all its users, and no op in any round below the bound shares a
    resource with *j*), order-preserving within every conflict chain
    (conflicting ops get strictly increasing colors in op order), and
    never worse than the greedy contiguous splitter (by induction: every
    hard predecessor of *j* has a strictly smaller greedy round, so the
    bound never exceeds *j*'s greedy round).  O(n) over footprint sizes.
    """
    last: dict = {}
    colors: List[int] = []
    for fp in footprints:
        c = 0
        for res in fp:
            p = last.get(res)
            if p is not None and p + 1 > c:
                c = p + 1
        for res in fp:
            last[res] = c
        colors.append(c)
    return colors


def _colors_to_rounds(colors: Sequence[int]) -> List[np.ndarray]:
    n_rounds = (max(colors) + 1) if len(colors) else 1
    rounds: List[List[int]] = [[] for _ in range(n_rounds)]
    for j, c in enumerate(colors):
        rounds[c].append(j)
    return [np.asarray(r, np.int64) for r in rounds]


def conflict_rounds(kids, s1, s2) -> List[np.ndarray]:
    """Split a miss subset (op order) into conflict-free rounds by
    chain-depth graph coloring: within a round all keys, replica sets and
    shared sets are distinct, and any two ops that share one of those
    resources land in rounds ordered like the ops — so committing the
    rounds in order IS the sequential op order for every conflict chain.
    Returns index arrays into the subset (ascending within each round);
    concatenated they are a permutation of ``range(len(kids))``.  Never
    more rounds than ``conflict_rounds_greedy``."""
    fps = [((0, k), (1, a), (2, b))
           for k, a, b in zip(np.asarray(kids).tolist(),
                              np.asarray(s1).tolist(),
                              np.asarray(s2).tolist())]
    return _colors_to_rounds(color_rounds(fps))


def conflict_rounds_greedy(kids, s1, s2) -> List[np.ndarray]:
    """The PR-5 splitter (kept as the coloring property-test oracle):
    maximal contiguous conflict-free segments in op order."""
    rounds: List[np.ndarray] = []
    cur: List[int] = []
    seen_k, seen_1, seen_2 = set(), set(), set()
    for i, (k, a, b) in enumerate(zip(np.asarray(kids).tolist(),
                                      np.asarray(s1).tolist(),
                                      np.asarray(s2).tolist())):
        if k in seen_k or a in seen_1 or b in seen_2:
            rounds.append(np.asarray(cur, np.int64))
            cur = []
            seen_k, seen_1, seen_2 = set(), set(), set()
        cur.append(i)
        seen_k.add(k)
        seen_1.add(a)
        seen_2.add(b)
    rounds.append(np.asarray(cur, np.int64))
    return rounds


def round_masks(rounds: List[np.ndarray], n_rounds: int,
                width: int) -> np.ndarray:
    """Pack conflict rounds into a dense ``[n_rounds, width]`` bool mask
    matrix (rows beyond ``len(rounds)`` are empty — a fully masked pass is
    a no-op), the shape the one-jit round scan consumes."""
    masks = np.zeros((n_rounds, width), bool)
    for r, idxs in enumerate(rounds):
        masks[r, idxs] = True
    return masks


def make_miss_pass(W1: int, W2: int, KS: int):
    """Build the vectorized miss pass for one tier geometry (W1/W2 = tier
    way counts, i.e. the trash-way indices; KS = TSU shard count).

    The returned function has the signature
    ``pass_(af, ops, masks, rep, node, rd, wr) -> (af, res)`` where ``af``
    is the fabric state pytree (arrays._AF), ``ops`` is the packed
    ``[4, M]`` int32 op block (rows: kid, replica set, shared set, TSU
    shard; padded lanes are all-zero and masked out), ``masks`` is the
    [R, M] conflict-round matrix (each row one conflict-free round, from
    ``conflict_rounds``), rep/node are scalars (one replica per
    read_batch call), and ``res`` is the packed [7, M] per-op result
    block (``RES_FIELDS`` order) of the op-scan's read path.

    The rounds run as ONE ``lax.scan`` with the fabric state as carry;
    each round body is the read path of ``arrays._build_run``'s step
    function re-expressed over a whole conflict-free round at once —
    every lease decision is the same ``core.state`` call the scan makes.
    Under graph-colored rounds the in-scan LRU ticks are provisional
    (execution-order ranks); the scan carries each lane's touch/fill
    flags and a post-scan permutation LUT remaps every provisional tick
    to the exact op-order value (identity for contiguous rounds) — see
    the module docstring and DESIGN.md §12b.
    """
    i32 = jnp.int32
    b2i = _b2i

    def round_body(af, out, act, kids, s1, s2, shard, rep, node, rd, wr):
        M = kids.shape[0]
        reps = jnp.full((M,), rep, i32)
        nodes = jnp.full((M,), node, i32)
        zt = jnp.zeros_like(shard)

        # ---- fused per-lane round math (kernels.tier_pass.miss_round):
        # replica probe, shared probe, Algorithm 3 TSU read grant and
        # both install levels in ONE Pallas grid pass — the same
        # ``core.state``/``core.protocol`` rules the op-scan applies,
        # per DESIGN.md §12c.  Only the cross-lane state scatters
        # (self-invalidation, LRU touch/fill, TSU commit) stay outside.
        (th1, h1, way1, th2, h2, way2, fndF, tway, mwts, mrts, nmem, ovf,
         nwA, nrA, nw1, nr1) = K.miss_round(
            af.rp.tag[reps, s1][..., :-1], af.rp.rts[reps, s1][..., :-1],
            af.sh.tag[nodes, s2][..., :-1], af.sh.rts[nodes, s2][..., :-1],
            af.sh.wts[nodes, s2][..., :-1],
            af.tsu.tag[shard, zt][..., :-1],
            af.tsu.memts[shard, zt][..., :-1],
            af.rp.cts[reps], af.sh.cts[nodes], kids, b2i(act),
            jnp.broadcast_to(jnp.asarray(rd, i32), (M,)))

        # ---- replica classification + self-invalidate (ReplicaCache.get)
        hit_ver = af.rp.ver[reps, s1, way1]
        hit_gs = af.rp_gseq[reps, s1, way1]
        miss = act & ~h1
        coh = miss & th1
        comp = miss & ~th1
        w1d = jnp.where(coh, way1, W1)
        rp_tag = af.rp.tag.at[reps, s1, w1d].set(
            jnp.where(coh, S.INVALID, af.rp.tag[reps, s1, w1d]))

        # ---- shared self-invalidate (SharedCache.get, on a replica miss)
        sh_ver = af.sh.ver[nodes, s2, way2]
        sh_gs = af.sh_gseq[nodes, s2, way2]
        coh2 = th2 & ~h2
        w2d = jnp.where(coh2, way2, W2)
        sh_tag = af.sh.tag.at[nodes, s2, w2d].set(
            jnp.where(coh2, S.INVALID, af.sh.tag[nodes, s2, w2d]))

        # ---- commit the round's TSU grants (state rules) + metadata
        need_mm = miss & ~h2
        tsu2 = S.tsu_commit_batch(af.tsu, shard, zt, tway, kids, nmem,
                                  fndF)
        mver = jnp.where(fndF, af.tsu_ver[shard, zt, tway], -1)
        mgs = jnp.where(fndF, af.tsu_gseq[shard, zt, tway], -1)
        home_miss = shard != node % KS

        # ---- response chain (what travels up to each tier)
        resp_found = h2 | fndF
        resp_ver = jnp.where(h2, sh_ver, mver)
        resp_gs = jnp.where(h2, sh_gs, mgs)

        # ---- provisional tick math (execution-order ranks): per op the
        # touch bump precedes the install bump, so op i's touch writes
        # tick0 + c[i] - fill[i] and its install tick0 + c[i] with
        # c = cumsum(touch + fill) — prefix sums over lane (= execution)
        # order.  Relative order within any one set equals op order (the
        # coloring invariant), so probes/victims are exact; the post-scan
        # LUT rewrites the absolute values to op-order ranks.
        c1 = jnp.cumsum(b2i(th1) + b2i(resp_found))
        lru_t1 = af.rp_tick[rep] + c1 - b2i(resp_found)
        lru_f1 = af.rp_tick[rep] + c1
        c2 = jnp.cumsum(b2i(th2) + b2i(fndF))
        lru_t2 = af.sh_tick[node] + c2 - b2i(fndF)
        lru_f2 = af.sh_tick[node] + c2

        def tier_fill(tag, lru, arrays, idx, st, th, touch_lru, way,
                      fill_c, vals, fill_lru, trash):
            """Touch + victim + fill on one (already-dropped) tier: the
            LRU touch refresh, then the packed install at the victim way
            — direct per-field scatters so the round scan updates the
            carried arrays in place."""
            wt = jnp.where(th, way, trash)
            lru = lru.at[idx, st, wt].set(
                jnp.where(th, touch_lru, lru[idx, st, wt]))
            vic = S.victim(tag, lru, idx, st)
            evicted = fill_c & (tag[idx, st, vic] != S.INVALID)
            wf = jnp.where(fill_c, vic, trash)

            def put(a, v):
                return a.at[idx, st, wf].set(
                    jnp.where(fill_c, v, a[idx, st, wf]))

            outs = [put(a, v) for a, v in arrays]
            return put(tag, vals), put(lru, fill_lru), outs, evicted

        sh_tag2, sh_lru2, (sh_wts2, sh_rts2, sh_ver2, sh_gseq2), evF = \
            tier_fill(sh_tag, af.sh.lru,
                      [(af.sh.wts, nwA), (af.sh.rts, nrA),
                       (af.sh.ver, mver), (af.sh_gseq, mgs)],
                      nodes, s2, th2, lru_t2, way2, fndF, kids, lru_f2, W2)
        rp_tag2, rp_lru2, (rp_wts2, rp_rts2, rp_ver2, rp_gseq2), ev1 = \
            tier_fill(rp_tag, af.rp.lru,
                      [(af.rp.wts, nw1), (af.rp.rts, nr1),
                       (af.rp.ver, resp_ver), (af.rp_gseq, resp_gs)],
                      reps, s1, th1, lru_t1, way1, resp_found, kids,
                      lru_f1, W1)

        # ---- counters: the scan's per-read gv/rv calls, summed per round
        n = lambda b: jnp.sum(b2i(b))
        b12, b2m, big = S.link_bytes(n(miss), n(need_mm),
                                     n(need_mm & home_miss))
        g2 = af.g + _gsum(
            reads=n(act), l1_hits=n(h1), l2_hits=n(h2), l1_to_l2=n(miss),
            coh_miss_l1=n(coh), coh_miss_l2=n(coh2),
            self_invalidations=n(coh) + n(coh2), compulsory=n(comp),
            l2_to_mm=n(need_mm), pcie_blocks=n(need_mm & home_miss),
            refetches=n(resp_found), overflow_reinits=n(ovf),
            capacity_evictions=n(evF) + n(ev1),
            bytes_l1_l2=b12, bytes_l2_mm=b2m, bytes_inter_gpu=big)
        r2 = af.r.at[rep].add(_rsum(
            reads=n(act), l1_hits=n(h1), l2_hits=n(h2), l1_to_l2=n(miss),
            coh_miss_l1=n(coh), coh_miss_l2=n(coh2),
            self_invalidations=n(coh) + n(coh2), compulsory=n(comp),
            refetches=n(resp_found),
            capacity_evictions=n(evF) + n(ev1)))

        af = af._replace(
            rp=af.rp._replace(tag=rp_tag2, wts=rp_wts2, rts=rp_rts2,
                              ver=rp_ver2, lru=rp_lru2),
            rp_gseq=rp_gseq2,
            rp_tick=af.rp_tick.at[rep].add(
                jnp.sum(b2i(th1) + b2i(resp_found))),
            sh=af.sh._replace(tag=sh_tag2, wts=sh_wts2, rts=sh_rts2,
                              ver=sh_ver2, lru=sh_lru2),
            sh_gseq=sh_gseq2,
            sh_tick=af.sh_tick.at[node].add(jnp.sum(b2i(th2) + b2i(fndF))),
            tsu=tsu2, g=g2, r=r2)

        vals = jnp.stack([
            b2i(h1 | resp_found),
            jnp.where(h1, hit_ver, jnp.where(resp_found, resp_ver, -1)),
            jnp.where(h1, hit_gs, jnp.where(resp_found, resp_gs, -1)),
            jnp.where(h1, 0, jnp.where(h2, 1, jnp.where(fndF, 2, 3))),
            jnp.where(fndF, mwts, 0), jnp.where(fndF, mrts, 0),
            b2i(fndF)])                               # RES_FIELDS order
        return (af, jnp.where(act[None, :], vals, out),
                th1, resp_found, th2, fndF)

    def pass_(af, ops, masks, rep, node, rd, wr):
        kids, s1, s2, shard = ops[0], ops[1], ops[2], ops[3]
        M = kids.shape[0]
        out0 = jnp.zeros((len(RES_FIELDS), M), i32)
        z0 = jnp.zeros((M,), i32)
        t0_rp = af.rp_tick[rep]
        t0_sh = af.sh_tick[node]

        def step(carry, act):
            af, out, fT1, fF1, fT2, fF2 = carry
            af, out, th1, rf, th2, ff = round_body(
                af, out, act, kids, s1, s2, shard, rep, node, rd, wr)
            return (af, out, fT1 + b2i(th1), fF1 + b2i(rf),
                    fT2 + b2i(th2), fF2 + b2i(ff)), None

        (af, out, fT1, fF1, fT2, fF2), _ = jax.lax.scan(
            step, (af, out0, z0, z0, z0, z0), masks)

        # ---- exact-LRU remap (DESIGN.md §12b): every provisional tick is
        # t0 + (execution-order rank of its event); the LUT sends that
        # rank to t0 + (op-order rank).  Events are lane-major pairs
        # (touch, fill) — the op-order event sequence — and each lane sits
        # in exactly one round, so the provisional rank decomposes into
        # `events in earlier rounds` + `in-round lane-prefix rank`.
        mi = masks.astype(i32)
        rnd = jnp.argmax(mi, axis=0)              # [M] round of each lane
        lane2 = jnp.repeat(rnd, 2)
        pos2 = jnp.arange(2 * M)

        def remap(row, f_touch, f_fill, t0):
            fl = jnp.stack([f_touch, f_fill], axis=1).reshape(-1)   # [2M]
            exact = jnp.cumsum(fl)                # op-order rank (1-based)
            per_round = mi @ (f_touch + f_fill)
            base = jnp.cumsum(per_round) - per_round
            inround = jnp.cumsum(jnp.repeat(mi, 2, axis=1) * fl[None, :],
                                 axis=1)
            prov = base[lane2] + inround[lane2, pos2]
            idx = jnp.where(fl > 0, prov, 2 * M + 1)
            lut = jnp.zeros((2 * M + 2,), i32).at[idx].set(
                jnp.where(fl > 0, t0 + exact, 0))
            d = row - t0                          # >0 iff written this pass
            return jnp.where(d > 0, lut[jnp.clip(d, 0, 2 * M + 1)], row)

        af = af._replace(
            rp=af.rp._replace(lru=af.rp.lru.at[rep].set(
                remap(af.rp.lru[rep], fT1, fF1, t0_rp))),
            sh=af.sh._replace(lru=af.sh.lru.at[node].set(
                remap(af.sh.lru[node], fT2, fF2, t0_sh))))
        return af, out

    return pass_


# ------------------------------------------------------ batched write pass
# The packed per-op result block of the write pass ([6, M] int32): each op
# is a posted write, so the only externally visible output is its drain —
# dcount (0/1) plus the drained grant's key/version/lease/gseq, exactly the
# op-scan's dlog_* record restricted to the one-drain-per-write case.
WRITE_RES_FIELDS = ("dcount", "dlog_key", "dlog_ver", "dlog_wts",
                    "dlog_rts", "dlog_gseq")

# the per-lane drain schedule block handed to the write pass ([7, M] int32)
WRITE_SCHED_FIELDS = ("drain", "dkey", "drep", "dwl", "dshard", "ds1",
                      "ds2")


def write_schedule(kids, s1, s2, shard, rep, wl, pending, maxif,
                   splitter: str = "colored"):
    """Resolve a write batch's drain schedule and split it into
    conflict-free rounds for the lane-static batched write pass.

    The bounded ring's drain schedule is **static in op index**: with L0
    pending entries at batch start, op j (0-based) drains the queue head
    iff ``L0 + j + 1 > maxif`` — so this host-side simulation resolves
    every drained entry exactly, independent of round assignment.

    ``pending`` is the node's queue at batch start, oldest first, as
    ``(kid, s1, s2, shard, rep, wl)`` tuples (``wl`` = the write-lease
    override recorded when the entry was posted, -1 for the default);
    ``rep``/``wl`` describe this batch's pushes.  Returns ``(rounds,
    sched)`` where ``sched`` is the ``[7, n]`` int32
    ``WRITE_SCHED_FIELDS`` block (zeros on non-drain lanes) and
    ``rounds`` are index arrays into the batch (a permutation of
    ``range(n)`` when concatenated; ascending within each round).

    Round constraints (op footprints): a push claims its key and its
    ``(rep, s1)`` replica set; a drain claims the drained entry's TSU
    shard and ``(node, s2)`` shared set always, plus its key and
    ``(drep, s1)`` replica set unless the entry was pushed in the very
    round the drain lands in (the pass applies every pending install
    before any drain install, so a same-round drain re-probes the
    pending line exactly as the sequential scan would).  The ``colored``
    splitter is chain-depth coloring (see ``color_rounds``) with three
    *order* side constraints that keep the pass's running-maximum clock
    chains and the TSU allocation sequencer exact (DESIGN.md §12b):

      * a drain never lands in an earlier round than any prior drain
        (drains execute in op order globally — gseq ranks, the node
        clock chain and the per-replica clock chains then read in lane
        order = op order);
      * a push never lands in an earlier round than a prior drain whose
        entry belongs to the push's replica (the pending line's
        ``pend_cts`` must see that drain's replica-clock bump);
      * a drain of this replica's own entry never lands in an earlier
        round than any prior push (the prior pushes' ``pend_cts`` must
        NOT see this drain's bump; ties resolve in-round by exclusive
        prefix maxima).

    ``splitter="greedy"`` reproduces the PR-6 contiguous splitter (the
    property-test oracle; colored never uses more rounds)."""
    kids = np.asarray(kids).tolist()
    s1 = np.asarray(s1).tolist()
    s2 = np.asarray(s2).tolist()
    shard = np.asarray(shard).tolist()
    n = len(kids)
    wl = int(wl)

    # ---- static drain schedule: simulate the bounded ring on the host
    q = collections.deque((tuple(e), -1) for e in pending)
    drain = np.zeros((n,), np.int64)
    dent: List = [None] * n        # drained entry per op
    dpe: List = [None] * n         # in-batch push op of the drained entry
    for j in range(n):
        q.append(((kids[j], s1[j], s2[j], shard[j], rep, wl), j))
        if len(q) > maxif:
            e, pe = q.popleft()
            drain[j] = 1
            dent[j] = e
            dpe[j] = pe if pe >= 0 else None

    sched = np.zeros((len(WRITE_SCHED_FIELDS), n), np.int32)
    sched[0] = drain
    for j in range(n):
        if drain[j]:
            ek, e1, e2, esh, erep, ewl = dent[j]
            sched[1, j] = ek
            sched[2, j] = erep
            sched[3, j] = ewl
            sched[4, j] = esh
            sched[5, j] = e1
            sched[6, j] = e2

    if splitter == "greedy":
        colors = _write_colors_greedy(n, kids, s1, rep, drain, dent, dpe)
    else:
        colors = _write_colors_chain(n, kids, s1, rep, drain, dent, dpe)
    return _colors_to_rounds(colors) if n else [np.asarray([], np.int64)], \
        sched


def _write_colors_greedy(n, kids, s1, rep, drain, dent, dpe):
    """The PR-6 contiguous splitter, re-expressed over the static drain
    schedule: break before op j whenever its footprint intersects the
    open round's, with the same-round-push exemption re-evaluated after a
    break (the pushed entry may now sit in the previous round)."""
    colors: List[int] = []
    r = 0
    seen_k, seen_1, seen_2, seen_sh = set(), set(), set(), set()
    for j in range(n):
        def fp(r_):
            fk, f1, f2, fsh = {kids[j]}, {(rep, s1[j])}, set(), set()
            if drain[j]:
                ek, e1, e2, esh, erep, _ = dent[j]
                fsh.add(esh)
                f2.add(e2)
                pe = dpe[j]
                same_round = pe is not None and (pe == j or
                                                 colors[pe] == r_)
                if not same_round:
                    fk.add(ek)
                    f1.add((erep, e1))
            return fk, f1, f2, fsh

        fk, f1, f2, fsh = fp(r)
        if (fk & seen_k) or (f1 & seen_1) or (f2 & seen_2) \
                or (fsh & seen_sh):
            r += 1
            seen_k, seen_1, seen_2, seen_sh = set(), set(), set(), set()
            fk, f1, f2, fsh = fp(r)
        colors.append(r)
        seen_k |= fk
        seen_1 |= f1
        seen_2 |= f2
        seen_sh |= fsh
    return colors


def _write_colors_chain(n, kids, s1, rep, drain, dent, dpe):
    """Chain-depth coloring for the write storm (see ``write_schedule``
    docstring for the constraint system).  Hard resources take
    ``last[res] + 1``; the three order side constraints are soft (ties
    allowed).  A drain of an entry pushed in this batch at op ``pe`` is
    *exempt* from its key/replica-set resources only when it can land
    exactly in ``colors[pe]`` (the push's round, where the pass's
    pending-before-drain install order reproduces the sequential
    push-then-drain); otherwise the key conflict forces it at least one
    round later."""
    last: dict = {}
    colors: List[int] = []
    max_dc = -1                  # max color of any drain so far
    max_dc_rep: dict = {}        # ... of drains per drained-entry replica
    max_push = -1                # max color of any op (= push) so far
    for j in range(n):
        push_res = ((0, kids[j]), (1, rep, s1[j]))
        lb = max(0, max_dc_rep.get(rep, -1))
        for res in push_res:
            p = last.get(res)
            if p is not None and p + 1 > lb:
                lb = p + 1
        if not drain[j]:
            for res in push_res:
                last[res] = lb
            colors.append(lb)
            if lb > max_push:
                max_push = lb
            continue

        ek, e1, e2, esh, erep, _ = dent[j]
        d0_res = ((3, esh), (2, e2))
        dk_res = ((0, ek), (1, erep, e1))
        lb_ex = max(lb, max_dc)
        if erep == rep and max_push > lb_ex:
            lb_ex = max_push
        for res in d0_res:
            p = last.get(res)
            if p is not None and p + 1 > lb_ex:
                lb_ex = p + 1
        pe = dpe[j]
        if pe is not None and (pe == j or lb_ex <= colors[pe]):
            c = lb_ex if pe == j else colors[pe]
        else:
            c = lb_ex
            for res in dk_res:
                p = last.get(res)
                if p is not None and p + 1 > c:
                    c = p + 1
        for res in push_res + d0_res + dk_res:
            last[res] = c
        if c > max_dc:
            max_dc = c
        if c > max_dc_rep.get(erep, -1):
            max_dc_rep[erep] = c
        if c > max_push:
            max_push = c
        colors.append(c)
    return colors


def write_rounds_greedy(kids, s1, s2, shard, rep, wl, pending, maxif):
    """Greedy contiguous write rounds (the coloring property-test
    oracle) — ``write_schedule`` with ``splitter="greedy"``."""
    return write_schedule(kids, s1, s2, shard, rep, wl, pending, maxif,
                          splitter="greedy")


def _tier_install(tier, gseq_a, idx, st, key, wts, rts, ver, gs, lru_v,
                  th, way, active, trash):
    """Vectorized ``install_at``: in place on ``(th, way)``, else the
    victim way; LRU values are the caller's prefix-sum ranks.  The round
    contract guarantees all active ``(idx, st)`` sets are distinct, so
    the scatters commute with the sequential order."""
    vic = S.victim(tier.tag, tier.lru, idx, st)
    w0 = jnp.where(th, way, vic)
    evicted = active & ~th & (tier.tag[idx, st, w0] != S.INVALID)
    w = jnp.where(active, w0, trash)

    def pt(a, v):
        return a.at[idx, st, w].set(jnp.where(active, v, a[idx, st, w]))

    tier2 = tier._replace(tag=pt(tier.tag, key), wts=pt(tier.wts, wts),
                          rts=pt(tier.rts, rts), ver=pt(tier.ver, ver),
                          lru=pt(tier.lru, lru_v))
    return tier2, pt(gseq_a, gs), evicted


def make_write_pass(W1: int, W2: int, KS: int, NN: int, NR: int, Q: int,
                    MAXIF: int):
    """Build the lane-static vectorized write pass for one fabric
    geometry (W1/W2 = tier trash-way indices, KS = TSU shard count,
    NN/NR = node/replica counts, Q = ring capacity, MAXIF = max in-flight
    writes).

    The returned function has the signature
    ``pass_(af, ops, sched, masks, rep, node, wl, rd, wr) -> (af, res)``:
    ``ops`` is the packed [4, M] int32 op block (kid, s1, s2, shard),
    ``sched`` the [7, M] ``WRITE_SCHED_FIELDS`` drain-schedule block from
    ``write_schedule`` (every drained entry pre-resolved on the host —
    the ring is static in op index), ``masks`` the [R, M] round matrix,
    rep/node/wl scalars (one replica, one uniform write-lease override
    per ``write_batch`` call), and ``res`` the packed [6, M]
    ``WRITE_RES_FIELDS`` block.

    Everything round-independent hoists OUT of the round scan:

      * the real ring update — a single keep-last scatter at op-order
        slots ``(H0 + L0 + rank - 1) mod Q`` (two pushes collide mod Q
        only when exactly Q pushes apart, and the earlier one is
        provably drained before the later lands: the queue never holds
        Q entries since MAXIF + 1 <= Q - 1), with head/len advanced once
        by the batch totals;
      * the LRU tick ranks — 2-D prefix sums over per-replica increments
        from the batch-start ticks (op j's pending install writes its
        submitter rank minus its own drain's contribution; the drain
        install writes the drained replica's rank; the shared tier
        counts drains), with the tick counters advanced once.

    The round scan keeps only the state-dependent math, exactly the
    op-scan's write path over a whole conflict-free round at once:

      * ONE batched TSU commit per round (``state.tsu_commit_write_batch``
        — the round contract guarantees distinct keys and at most one
        write per shard);
      * clocks via running maxima (DESIGN.md §9c prefix-sum style): the
        TSU grant is clock-independent, so the node clock after drain i
        is ``max(cts0, cummax(mwts)_i)`` and each replica clock chains
        the same way over its own drains — closed forms of the
        sequential ``install``/``cts_after_write`` recurrences; the
        scheduler's order side constraints make lane order within and
        across rounds equal drain op order, so the chains stay exact
        under coloring;
      * pending installs (store-buffer lines) against the pre-round
        replica state, then the drain installs — whose probes run AFTER
        the pending scatters so a drain of a same-round push sees its
        pending line, exactly as the scan does.

    All rounds run inside ONE ``lax.scan``; on the sharded fabric the
    caller brackets the pass with the gather/scatter exchange
    (``arrays._xin``/``_xout``) so the full TSU table is assembled with
    ONE collective per batch.
    """
    i32 = jnp.int32
    b2i = _b2i
    NEG = _NEG

    def round_body(af, out, act, kids, s1, drain_l, dkey, drep, dwl,
                   dshard, ds1, ds2, lru_pend, lru_drain, lru_sh, rep,
                   node, rd, wr):
        M = kids.shape[0]
        iota = jnp.arange(M, dtype=i32)
        reps = jnp.full((M,), rep, i32)
        nodes = jnp.full((M,), node, i32)
        dr = act & drain_l

        # ---- ONE batched TSU write for the round's drains (state rules)
        dwl_eff = jnp.where(dwl >= 0, dwl, wr)
        (mwts, mrts, dver, gs, evict, ovf, tsu2, ver2, gseq2, seq2, nseq2,
         gnext2) = S.tsu_commit_write_batch(
            af.tsu, af.tsu_ver, af.tsu_gseq, af.tsu_seq, af.tsu_nseq,
            af.gseq_next, dshard, dkey, dwl_eff, rd, dr)

        # ---- clock chains: running maxima reproduce the sequential
        # install/cts_after_write recurrences (grants are clock-free)
        cts0n = af.sh.cts[node]
        run_mw = jax.lax.cummax(jnp.where(dr, mwts, NEG))
        nwA = jnp.maximum(cts0n, run_mw)
        nrA = jnp.maximum(nwA + 1, mrts)
        onehot_d = (jnp.arange(NR, dtype=i32)[:, None] == drep[None, :]) \
            & dr[None, :]
        runsA = jax.lax.cummax(jnp.where(onehot_d, nwA[None, :], NEG),
                               axis=1)
        cts0r = af.rp.cts
        nwB = jnp.maximum(cts0r[drep], runsA[drep, iota])
        nrB = jnp.maximum(nwB + 1, nrA)
        exclA = jnp.concatenate([jnp.full((NR, 1), NEG), runsA[:, :-1]],
                                axis=1)
        pend_cts = jnp.maximum(cts0r[rep], exclA[rep])

        # ---- pending installs (store-buffer lines: wts=rts=cts, ver=-1)
        # against the pre-round replica state, then the drain installs —
        # whose probes run AFTER the pending scatters so a drain of a
        # same-round push sees its pending line, exactly as the scan does
        negs = jnp.full((M,), -1, i32)
        thP, wayP = S.probe(af.rp.tag, reps, s1, kids)
        rpA, rpgA, evP = _tier_install(
            af.rp, af.rp_gseq, reps, s1, kids, pend_cts, pend_cts, negs,
            negs, lru_pend, thP & act, wayP, act, W1)
        thA, wayA = S.probe(af.sh.tag, nodes, ds2, dkey)
        sh2, shg2, ev1 = _tier_install(
            af.sh, af.sh_gseq, nodes, ds2, dkey, nwA, nrA, dver, gs,
            lru_sh, thA & dr, wayA, dr, W2)
        thB, wayB = S.probe(rpA.tag, drep, ds1, dkey)
        rp2, rpg2, ev2 = _tier_install(
            rpA, rpgA, drep, ds1, dkey, nwB, nrB, dver, gs, lru_drain,
            thB & dr, wayB, dr, W1)

        # ---- counters: the scan's per-write gv/rv calls, summed
        n = lambda b: jnp.sum(b2i(b))
        Pn = n(act)
        D = n(dr)
        cross = dr & (dshard != node % KS)
        b12, b2m, big = S.link_bytes(Pn, D, n(cross))
        g2 = af.g + _gsum(
            writes=Pn, l1_to_l2=Pn, l2_to_mm=D, write_throughs=D,
            pcie_blocks=n(cross), tsu_evictions=n(evict),
            overflow_reinits=n(ovf),
            capacity_evictions=n(evP) + n(ev1) + n(ev2),
            bytes_l1_l2=b12, bytes_l2_mm=b2m, bytes_inter_gpu=big)
        r2 = af.r.at[rep].add(_rsum(
            writes=Pn, l1_to_l2=Pn, capacity_evictions=n(evP)))
        r2 = r2.at[drep, RI["write_throughs"]].add(b2i(dr))
        r2 = r2.at[drep, RI["capacity_evictions"]].add(b2i(ev2))

        af = af._replace(
            rp=rp2._replace(cts=jnp.maximum(cts0r, runsA[:, -1])),
            rp_gseq=rpg2,
            sh=sh2._replace(cts=af.sh.cts.at[node].set(
                jnp.maximum(cts0n, run_mw[-1]))),
            sh_gseq=shg2,
            tsu=tsu2, tsu_ver=ver2, tsu_gseq=gseq2, tsu_seq=seq2,
            tsu_nseq=nseq2, gseq_next=gnext2, g=g2, r=r2)

        vals = jnp.stack([
            b2i(dr), jnp.where(dr, dkey, -1),
            jnp.where(dr, dver, -1), jnp.where(dr, mwts, -1),
            jnp.where(dr, mrts, -1), jnp.where(dr, gs, -1),
        ])                                       # WRITE_RES_FIELDS order
        return af, jnp.where(act[None, :], vals, out)

    def pass_(af, ops, sched, masks, rep, node, wl, rd, wr):
        kids, s1, s2, shard = ops[0], ops[1], ops[2], ops[3]
        drain_l = sched[0].astype(bool)
        dkey = sched[1]
        drep = jnp.clip(sched[2], 0, NR - 1)
        dwl = sched[3]
        dshard = sched[4]
        ds1 = sched[5]
        ds2 = sched[6]
        M = kids.shape[0]
        iota = jnp.arange(M, dtype=i32)
        act_any = jnp.any(masks, axis=0)
        dr_any = act_any & drain_l

        # ---- real ring update (lane-static): keep-last scatter at
        # op-order slots, head/len advanced once by the batch totals
        prank = jnp.cumsum(b2i(act_any))
        Pt = prank[-1]
        Dt = jnp.sum(b2i(dr_any))
        L0 = af.wq_len[node]
        H0 = af.wq_head[node]
        push_v = {"key": kids, "rep": jnp.full((M,), rep, i32),
                  "wl": jnp.full((M,), wl, i32), "shard": shard,
                  "set1": s1, "set2": s2}
        keep = act_any & (prank + Q > Pt)
        slot = (H0 + L0 + prank - 1) % Q
        nrow = jnp.where(keep, node, NN)        # OOB row -> dropped
        wq2 = {f: a.at[nrow, slot].set(push_v[f], mode="drop")
               for f, a in af.wq.items()}

        # ---- LRU tick ranks (lane-static): §9c prefix sums over
        # per-replica increments from the batch-start ticks
        onehot_d = (jnp.arange(NR, dtype=i32)[:, None] == drep[None, :]) \
            & dr_any[None, :]
        inc = b2i(act_any)[None, :] * b2i(
            jnp.arange(NR, dtype=i32)[:, None] == rep) + b2i(onehot_d)
        c = jnp.cumsum(inc, axis=1)
        tick0 = af.rp_tick
        lru_pend = tick0[rep] + c[rep] - b2i(dr_any & (drep == rep))
        lru_drain = tick0[drep] + c[drep, iota]
        lru_sh = af.sh_tick[node] + jnp.cumsum(b2i(dr_any))

        af = af._replace(
            rp_tick=tick0 + c[:, -1],
            sh_tick=af.sh_tick.at[node].add(Dt),
            wq=wq2, wq_head=af.wq_head.at[node].set((H0 + Dt) % Q),
            wq_len=af.wq_len.at[node].add(Pt - Dt))

        out0 = jnp.zeros((len(WRITE_RES_FIELDS), M), i32)

        def step(carry, act):
            af, out = carry
            return round_body(af, out, act, kids, s1, drain_l, dkey,
                              drep, dwl, dshard, ds1, ds2, lru_pend,
                              lru_drain, lru_sh, rep, node, rd, wr), None

        (af, out), _ = jax.lax.scan(step, (af, out0), masks)
        return af, out

    return pass_


# ------------------------------------------------------------- fence pass
# the per-lane fence schedule block ([8, D] int32): one lane per queued
# posted write, in node order then FIFO order — the exact host drain order
FENCE_SCHED_FIELDS = ("ent", "dkey", "drep", "dwl", "dshard", "ds1",
                      "ds2", "dnode")


def fence_schedule(entries) -> Tuple[List[np.ndarray], np.ndarray]:
    """Build the fence drain schedule: ``entries`` is every node's queue
    concatenated in node order (each oldest-first), as
    ``(kid, s1, s2, shard, rep, wl, node)`` tuples.  Returns ``(rounds,
    sched)`` with ``sched`` the [8, n] ``FENCE_SCHED_FIELDS`` block.

    Rounds are greedy contiguous segments over the drain footprint (key,
    replica set, shared set, TSU shard): a fence drains in strict host
    order, and the drain-order side constraint (every drain >= all prior
    drains) collapses chain-depth coloring to exactly this contiguous
    segmentation — so the greedy split is the colored split here."""
    n = len(entries)
    sched = np.zeros((len(FENCE_SCHED_FIELDS), n), np.int32)
    rounds: List[np.ndarray] = []
    cur: List[int] = []
    seen: set = set()
    for j, (k, a, b, sh, rep, wl, node) in enumerate(entries):
        sched[:, j] = (1, k, rep, wl, sh, a, b, node)
        fp = {(0, k), (1, rep, a), (2, node, b), (3, sh)}
        if fp & seen:
            rounds.append(np.asarray(cur, np.int64))
            cur = []
            seen = set()
        cur.append(j)
        seen |= fp
    rounds.append(np.asarray(cur, np.int64))
    return rounds, sched


def make_fence_pass(W1: int, W2: int, KS: int, NN: int, NR: int, Q: int):
    """Build the vectorized fence pass: drain EVERY node's posted-write
    queue (node order, FIFO within a node), then jump every client clock
    to the global maximum — the op-scan's ``_fence`` handler (DESIGN.md
    §11b) over conflict-free rounds.

    The returned function has the signature
    ``pass_(af, sched, masks, rd, wr) -> (af, res, gmax)``: ``sched`` is
    the [8, D] ``FENCE_SCHED_FIELDS`` block from ``fence_schedule``
    (padded lanes have ``ent == 0``), ``masks`` the [R, D] round matrix,
    and ``res`` the packed [6, D] ``WRITE_RES_FIELDS`` block (one drain
    record per lane).  A fence is drains-only — no pending installs —
    so each round is the write pass's drain half generalized to
    multi-node lanes: per-node clock chains via per-node running maxima,
    per-replica chains as before (lanes are host-ordered and the
    schedule is contiguous, so lane order IS drain order everywhere).
    The ring bookkeeping, LRU ranks and tick advances are lane-static
    and hoist out of the scan; after the scan every ``cts`` jumps to the
    global max — the §11b barrier that makes all prior writes globally
    visible."""
    i32 = jnp.int32
    b2i = _b2i
    NEG = _NEG

    def round_body(af, out, act, ent_l, dkey, drep, dwl, dshard, ds1,
                   ds2, dnode, lru_rp, lru_sh, rd, wr):
        D = dkey.shape[0]
        iota = jnp.arange(D, dtype=i32)
        dr = act & ent_l

        dwl_eff = jnp.where(dwl >= 0, dwl, wr)
        (mwts, mrts, dver, gs, evict, ovf, tsu2, ver2, gseq2, seq2, nseq2,
         gnext2) = S.tsu_commit_write_batch(
            af.tsu, af.tsu_ver, af.tsu_gseq, af.tsu_seq, af.tsu_nseq,
            af.gseq_next, dshard, dkey, dwl_eff, rd, dr)

        # ---- clock chains, generalized per node: each node's clock
        # chains over its own drains (lane order = host drain order)
        onehot_n = (jnp.arange(NN, dtype=i32)[:, None] == dnode[None, :]) \
            & dr[None, :]
        runsN = jax.lax.cummax(jnp.where(onehot_n, mwts[None, :], NEG),
                               axis=1)
        nwA = jnp.maximum(af.sh.cts[dnode], runsN[dnode, iota])
        nrA = jnp.maximum(nwA + 1, mrts)
        onehot_d = (jnp.arange(NR, dtype=i32)[:, None] == drep[None, :]) \
            & dr[None, :]
        runsA = jax.lax.cummax(jnp.where(onehot_d, nwA[None, :], NEG),
                               axis=1)
        nwB = jnp.maximum(af.rp.cts[drep], runsA[drep, iota])
        nrB = jnp.maximum(nwB + 1, nrA)

        # ---- installs: shared tier at the drained node, then the
        # drained replica's tier (no pending lines — fences only drain)
        thA, wayA = S.probe(af.sh.tag, dnode, ds2, dkey)
        sh2, shg2, ev1 = _tier_install(
            af.sh, af.sh_gseq, dnode, ds2, dkey, nwA, nrA, dver, gs,
            lru_sh, thA & dr, wayA, dr, W2)
        thB, wayB = S.probe(af.rp.tag, drep, ds1, dkey)
        rp2, rpg2, ev2 = _tier_install(
            af.rp, af.rp_gseq, drep, ds1, dkey, nwB, nrB, dver, gs,
            lru_rp, thB & dr, wayB, dr, W1)

        # ---- counters: the op-scan's per-drain calls, summed
        n = lambda b: jnp.sum(b2i(b))
        Dn = n(dr)
        cross = dr & (dshard != dnode % KS)
        _, b2m, big = S.link_bytes(jnp.int32(0), Dn, n(cross))
        g2 = af.g + _gsum(
            l2_to_mm=Dn, write_throughs=Dn, pcie_blocks=n(cross),
            tsu_evictions=n(evict), overflow_reinits=n(ovf),
            capacity_evictions=n(ev1) + n(ev2),
            bytes_l2_mm=b2m, bytes_inter_gpu=big)
        r2 = af.r.at[drep, RI["write_throughs"]].add(b2i(dr))
        r2 = r2.at[drep, RI["capacity_evictions"]].add(b2i(ev2))

        af = af._replace(
            rp=rp2._replace(cts=jnp.maximum(af.rp.cts, runsA[:, -1])),
            rp_gseq=rpg2,
            sh=sh2._replace(cts=jnp.maximum(af.sh.cts, runsN[:, -1])),
            sh_gseq=shg2,
            tsu=tsu2, tsu_ver=ver2, tsu_gseq=gseq2, tsu_seq=seq2,
            tsu_nseq=nseq2, gseq_next=gnext2, g=g2, r=r2)

        vals = jnp.stack([
            b2i(dr), jnp.where(dr, dkey, -1),
            jnp.where(dr, dver, -1), jnp.where(dr, mwts, -1),
            jnp.where(dr, mrts, -1), jnp.where(dr, gs, -1),
        ])                                       # WRITE_RES_FIELDS order
        return af, jnp.where(act[None, :], vals, out)

    def pass_(af, sched, masks, rd, wr):
        ent_l = sched[0].astype(bool)
        dkey = sched[1]
        drep = jnp.clip(sched[2], 0, NR - 1)
        dwl = sched[3]
        dshard = sched[4]
        ds1 = sched[5]
        ds2 = sched[6]
        dnode = jnp.clip(sched[7], 0, NN - 1)
        D = dkey.shape[0]
        iota = jnp.arange(D, dtype=i32)

        # ---- lane-static bookkeeping: LRU ranks from the batch-start
        # ticks, tick/ring advances applied once (nothing in-scan reads
        # them — the schedule block carries every drained entry)
        onehot_d = (jnp.arange(NR, dtype=i32)[:, None] == drep[None, :]) \
            & ent_l[None, :]
        onehot_n = (jnp.arange(NN, dtype=i32)[:, None] == dnode[None, :]) \
            & ent_l[None, :]
        cr = jnp.cumsum(b2i(onehot_d), axis=1)
        cn = jnp.cumsum(b2i(onehot_n), axis=1)
        lru_rp = af.rp_tick[drep] + cr[drep, iota]
        lru_sh = af.sh_tick[dnode] + cn[dnode, iota]
        cnt_n = cn[:, -1]
        af = af._replace(
            rp_tick=af.rp_tick + cr[:, -1],
            sh_tick=af.sh_tick + cnt_n,
            wq_head=(af.wq_head + cnt_n) % Q,
            wq_len=af.wq_len - cnt_n,
            g=af.g + _gsum(fences=jnp.int32(1)))

        out0 = jnp.zeros((len(WRITE_RES_FIELDS), D), i32)

        def step(carry, act):
            af, out = carry
            return round_body(af, out, act, ent_l, dkey, drep, dwl,
                              dshard, ds1, ds2, dnode, lru_rp, lru_sh,
                              rd, wr), None

        (af, out), _ = jax.lax.scan(step, (af, out0), masks)

        # ---- §11b barrier: every client clock jumps to the global max
        gmax = jnp.maximum(jnp.max(af.rp.cts), jnp.max(af.sh.cts))
        af = af._replace(
            rp=af.rp._replace(cts=jnp.full_like(af.rp.cts, gmax)),
            sh=af.sh._replace(cts=jnp.full_like(af.sh.cts, gmax)))
        return af, out, gmax

    return pass_


# -------------------------------------------------- collective accounting
def collective_counts(jaxpr) -> dict:
    """Walk a (closed) jaxpr and count collective primitives: ``total``
    occurrences and how many sit inside a scan/while body (``in_loop``).
    A collective inside a loop executes once PER ITERATION — the exact
    O(ops)-collectives failure mode the batched pipeline removes — so the
    parity suite pins ``in_loop == 0`` and ``total`` == the per-batch
    collective budget for ``pipeline="batched"``.  (The miss pass's round
    scan is collective-free: its one gather sits OUTSIDE the scan.)

    The walker itself now lives in ``repro.obs.xprof`` (the observability
    layer's static cost probe, which also reports per-primitive counts
    and compiled FLOPs/bytes); this wrapper keeps the parity suite's
    two-field view."""
    from repro.obs.xprof import jaxpr_collectives

    c = jaxpr_collectives(jaxpr)
    return {"total": c["total"], "in_loop": c["in_loop"]}
