"""Vectorized MGPU memory-hierarchy simulator.

TPU-native re-formulation of the paper's event-driven MGPUSim model: the
protocol advances in *rounds* (one instruction per CU per round) inside a
``lax.scan``; every L1/L2/TSU probe, fill and timestamp update is executed as
a dense array operation batched over all 128+ CUs at once.  Since the
array-native refactor (DESIGN.md §7) the engine holds its hierarchy as
``core.state`` pytrees (``TierState`` for L1/L2, ``TSUState`` for the TSU)
and every transition — probe, victim choice, TSU grant, fused probe+install
— is a call into ``core.state``; this file only contributes *timing* (a
mean-value queueing model: fixed component latencies plus per-round
occupancy delays at L2 banks / HBM stacks / PCIe links) and the per-config
routing/gating policy.  The L1 and L2 probe+install math is served by
``kernels.lease_probe`` (compiled Pallas on TPU/GPU, interpret fallback on
CPU, selected at runtime) via ``state.tier_probe``.

Two drivers (DESIGN.md §5):

- ``simulate(cfg, ops, addrs)`` — one (config, trace) cell; returns the
  per-round read log and final state for litmus-level inspection.
- ``sweep(cfgs, ops, addrs)`` — the batched figure engine: ops/addrs are a
  padded ``[B, NC, R]`` benchmark batch (``traces.pack_batch``), configs are
  grouped by ``sysconfig.static_key`` and stacked into vmappable pytrees,
  and ONE jit produces the whole (config x benchmark) result matrix.

Modeled systems (sysconfig.py): RDMA-WB-NC, RDMA-WB-C-HMG (VI-style home
directory over PCIe), SM-WB-NC, SM-WT-NC, SM-WT-C-HALCONE.

Approximations vs. the event-driven original (documented in DESIGN.md §4):
lockstep instruction issue (per-CU latencies still accrue independently);
same-round same-address writes share one logical tick (ties broken by
physical order, as §3.2); queueing delay is the mean of the round's occupancy
rather than a per-message schedule.

Trace op encoding: 0=nop, 1=read, 2=write, 3=fence (kernel boundary -> cts
jumps to the global maximum), 4=compute (addr field = cycles).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import protocol, state as S
from repro.core.state import INVALID, RES_FIELDS, TSUState, TierState
from repro.core.sysconfig import SystemConfig, stack_configs, static_key
from repro.obs import trace as obs

NOP, READ, WRITE, FENCE, COMPUTE = 0, 1, 2, 3, 4


class SimState(NamedTuple):
    l1: TierState          # per CU               [NC, S1, W1+1]
    l2: TierState          # per (gpu*banks)      [NL2, S2, W2+1]
    l2_dirty: jnp.ndarray  # WB policy bit        [NL2, S2, W2+1]
    tsu: TSUState          # per HBM stack        [NH, ST, TW+1]
    # main memory (authoritative data versions)
    mm_ver: jnp.ndarray    # [A]
    # HMG directory
    dir_sharers: jnp.ndarray  # [A, G] bool (hmg only; [1,1] otherwise)
    # timing / counters
    time: jnp.ndarray      # [NC] f32
    ctr: dict              # scalars f32

    # -- flat-field views kept for litmus/demo inspection of results --
    l1_tag = property(lambda s: s.l1.tag)
    l1_rts = property(lambda s: s.l1.rts)
    l1_wts = property(lambda s: s.l1.wts)
    l1_ver = property(lambda s: s.l1.ver)
    l1_lru = property(lambda s: s.l1.lru)
    l1_cts = property(lambda s: s.l1.cts)
    l2_tag = property(lambda s: s.l2.tag)
    l2_rts = property(lambda s: s.l2.rts)
    l2_wts = property(lambda s: s.l2.wts)
    l2_ver = property(lambda s: s.l2.ver)
    l2_lru = property(lambda s: s.l2.lru)
    l2_cts = property(lambda s: s.l2.cts)
    tsu_tag = property(lambda s: s.tsu.tag)
    tsu_memts = property(lambda s: s.tsu.memts)


COUNTERS = ("l1_to_l2", "l2_to_mm", "l1_hits", "l2_hits", "coh_miss_l1",
            "coh_miss_l2", "wb_evictions", "inval_msgs", "pcie_blocks",
            "reads", "writes",
            # Fig-10 per-link traffic (state.link_bytes): data blocks are
            # BLOCK_BYTES, invalidations CTRL_BYTES; HALCONE's inter-GPU
            # bytes carry no invalidation component by construction.
            "bytes_l1_l2", "bytes_l2_mm", "bytes_inter_gpu")


def init_state(cfg: SystemConfig, n_addr: int) -> SimState:
    NC = cfg.n_cus
    NL2 = cfg.n_gpus * cfg.l2_banks
    G = cfg.n_gpus if cfg.protocol == "hmg" else 1
    A = n_addr if cfg.protocol == "hmg" else 1
    return SimState(
        l1=S.init_tier(NC, cfg.l1_sets, cfg.l1_ways),
        l2=S.init_tier(NL2, cfg.l2_sets, cfg.l2_ways),
        l2_dirty=jnp.zeros((NL2, cfg.l2_sets, cfg.l2_ways + 1), bool),
        tsu=S.init_tsu(cfg.n_hbm, cfg.tsu_sets, cfg.tsu_ways),
        mm_ver=jnp.zeros((n_addr,), jnp.int32),
        dir_sharers=jnp.zeros((A, G), bool),
        time=jnp.zeros((NC,), jnp.float32),
        ctr={k: jnp.zeros((), jnp.float32) for k in COUNTERS},
    )


def _queue_delay(cache_idx, active, n_queues, service):
    """Saturation queueing: a round's n requests to one port drain serially,
    so each waits ~(n-1)*service (calibrated against Fig 8's saturation)."""
    counts = jnp.zeros((n_queues,), jnp.float32).at[
        jnp.where(active, cache_idx, 0)].add(active.astype(jnp.float32))
    mine = counts[cache_idx]
    return jnp.where(active, jnp.maximum(mine - 1.0, 0.0) * service, 0.0)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@functools.lru_cache(maxsize=64)
def _sim_fn(cfg: SystemConfig, n_addr: int, T: int):
    step = _make_round(cfg, n_addr)

    def run(state, ops_t, addrs_t):
        return jax.lax.scan(step, state,
                            (ops_t, addrs_t, jnp.arange(T, dtype=jnp.int32)))

    return jax.jit(run)


def simulate(cfg: SystemConfig, ops, addrs):
    """Host wrapper: buckets shapes (compile reuse), runs the scan."""
    ops = np.asarray(ops, np.int32)
    addrs = np.asarray(addrs, np.int32)
    n_addr = _next_pow2(int(addrs.max()) + 2)
    T0 = ops.shape[1]
    T = _next_pow2(T0)
    if T != T0:                              # pad with NOPs (no effect)
        pad = ((0, 0), (0, T - T0))
        ops = np.pad(ops, pad)
        addrs = np.pad(addrs, pad)
    state = init_state(cfg, n_addr)
    with obs.span("engine.simulate.scan", cat="engine", T=T):
        state, res_log = _sim_fn(cfg, n_addr, T)(state, jnp.asarray(ops).T,
                                                 jnp.asarray(addrs).T)
        obs.fence(res_log, "engine.simulate.device")
    with obs.span("engine.simulate.decode", cat="engine"):
        # scan emits the packed per-round result block [T, 7, NC]
        # (core.state.RES_FIELDS); reshape to per-field [NC, T0] views
        res_np = np.asarray(res_log).transpose(1, 2, 0)[:, :, :T0]
        fields = dict(zip(RES_FIELDS, res_np))
        read_log = np.where(ops[:, :T0] == READ, fields["version"], -1)
    # Runtime: CUs within a GPU hide each other's latency (warp interleaving)
    # -> per-GPU throughput ~ mean CU time; GPUs don't share work -> max.
    per_gpu = state.time.reshape(cfg.n_gpus, cfg.cus_per_gpu).mean(axis=1)
    return {
        "cycles": jnp.max(per_gpu),
        "makespan_max": jnp.max(state.time),
        "per_cu_time": state.time,
        "counters": state.ctr,
        "read_log": read_log,  # [NC, T] version returned (-1 = no read)
        "res_log": fields,     # {RES_FIELDS: [NC, T]} per-op result block
        "state": state,
    }


# --------------------------------------------------------------- sweep
@functools.partial(jax.jit, static_argnames=("n_addr",))
def _sweep_run(groups, ops_bt, addrs_bt, *, n_addr):
    """groups: tuple of stacked SystemConfig pytrees (data leaves [Ci]);
    ops_bt/addrs_bt: [B, T, NC].  Returns a tuple of per-group result
    pytrees with leading [Ci, B] axes — the whole grid in one jit."""
    T = ops_bt.shape[1]

    def one(cfg, ops_t, addrs_t):
        step = _make_round(cfg, n_addr, with_log=False)
        st, _ = jax.lax.scan(step, init_state(cfg, n_addr),
                             (ops_t, addrs_t,
                              jnp.arange(T, dtype=jnp.int32)))
        per_gpu = st.time.reshape(cfg.n_gpus, cfg.cus_per_gpu).mean(axis=1)
        return {"cycles": jnp.max(per_gpu), "makespan_max": jnp.max(st.time),
                "counters": st.ctr}

    over_b = jax.vmap(one, in_axes=(None, 0, 0))      # benchmark axis
    over_cb = jax.vmap(over_b, in_axes=(0, None, None))  # config axis
    return tuple(over_cb(g, ops_bt, addrs_bt) for g in groups)


def sweep(cfgs: Sequence[SystemConfig], ops, addrs):
    """Batched (config x benchmark) sweep — the figure engine.

    ops/addrs: ``[B, NC, R]`` (``traces.pack_batch``); every config must
    have ``n_cus == NC``.  Configs are grouped by structural signature
    (``sysconfig.static_key``); each group is stacked into one pytree and
    double-vmapped (configs x benchmarks) over a shared scan, all groups
    inside ONE jit.  Returns ``{"cycles": [C, B], "makespan_max": [C, B],
    "counters": {k: [C, B]}}`` in the input config order.  Identical math
    to per-cell ``simulate`` (tests/test_sweep.py asserts parity); the
    per-round read log is elided to keep the batch memory-light."""
    cfgs = list(cfgs)
    ops = np.asarray(ops, np.int32)
    addrs = np.asarray(addrs, np.int32)
    if ops.ndim != 3:
        raise ValueError(f"expected [B, NC, R] batch, got {ops.shape}")
    B, NC, R = ops.shape
    for c in cfgs:
        if c.n_cus != NC:
            raise ValueError(f"config {c.name} has n_cus={c.n_cus}, "
                             f"traces have NC={NC}")
    n_addr = _next_pow2(int(addrs.max()) + 2)
    with obs.span("engine.sweep.pack", cat="engine", B=B, NC=NC):
        T = _next_pow2(R)
        if T != R:                           # pad with NOPs (no effect)
            pad = ((0, 0), (0, 0), (0, T - R))
            ops = np.pad(ops, pad)
            addrs = np.pad(addrs, pad)
        ops_bt = jnp.asarray(ops.transpose(0, 2, 1))     # [B, T, NC]
        addrs_bt = jnp.asarray(addrs.transpose(0, 2, 1))
        # group configs by static structure, first-appearance order
        order: dict = {}
        for i, c in enumerate(cfgs):
            order.setdefault(static_key(c), []).append(i)
        groups = tuple(stack_configs([cfgs[i] for i in idx])
                       for idx in order.values())
    with obs.span("engine.sweep.scan", cat="engine",
                  n_groups=len(groups)):
        outs = _sweep_run(groups, ops_bt, addrs_bt, n_addr=n_addr)
        obs.fence(outs, "engine.sweep.device")
    with obs.span("engine.sweep.decode", cat="engine"):
        # scatter group rows back to the input config order
        flat_idx = [i for idx in order.values() for i in idx]
        perm = np.argsort(flat_idx)
        merged = jax.tree_util.tree_map(
            lambda *xs: np.concatenate([np.asarray(x) for x in xs], 0),
            *outs)
        return jax.tree_util.tree_map(lambda x: x[perm], merged)


def _make_round(cfg: SystemConfig, n_addr: int, with_log: bool = True):
    NC = cfg.n_cus
    G, NB, CU = cfg.n_gpus, cfg.l2_banks, cfg.cus_per_gpu
    NL2 = G * NB
    NH = cfg.n_hbm
    coherent = cfg.protocol == "halcone"
    hmg = cfg.protocol == "hmg"
    rdma = cfg.topology == "rdma"
    wb = cfg.l2_policy == "wb"
    cu_ids = jnp.arange(NC, dtype=jnp.int32)
    gpu_of = cu_ids // CU

    def home_gpu(addr):
        return (addr // cfg.page_blocks) % G

    def hbm_of(addr):
        return (addr // cfg.page_blocks) % NH

    def round_step(st: SimState, xs):
        op, addr, rnd = xs
        is_read = op == READ
        is_write = op == WRITE
        is_fence = op == FENCE
        is_comp = op == COMPUTE
        mem = is_read | is_write
        ctr = dict(st.ctr)

        # ---------------- request routing (addr-only, no probes) ----------
        s1 = addr % cfg.l1_sets
        remote = (home_gpu(addr) != gpu_of) & rdma
        # L2 instance: SM -> own GPU; RDMA-NC -> home GPU's L2;
        # HMG -> local first, then home.
        bank = addr % NB
        own_l2 = gpu_of * NB + bank
        home_l2 = home_gpu(addr) * NB + bank
        if rdma and not hmg:
            l2c = jnp.where(remote, home_l2, own_l2)
        else:
            l2c = own_l2
        s2 = (addr // NB) % cfg.l2_sets
        hb = hbm_of(addr)

        # ---------------- TSU lease math (values; gating applied later) ---
        # The grant (mwts, mrts) a request WOULD get from the TSU.  Whether
        # it reaches the TSU (need_mm) is only known after the L1/L2 probes;
        # state updates are gated below.
        if coherent:
            ts_set = addr % cfg.tsu_sets
            hitT, wayT = S.probe(st.tsu.tag, hb, ts_set, addr)
            vT = S.victim(st.tsu.tag, st.tsu.memts, hb, ts_set)
            wayT = jnp.where(hitT, wayT, vT)
            memts = jnp.where(hitT, st.tsu.memts[hb, ts_set, wayT], 0)
            grant = S.tsu_lease(memts, is_write, cfg.rd_lease, cfg.wr_lease)
            mwts, mrts, new_memts = grant.wts, grant.rts, grant.new_memts
        else:
            # trivial grant: [0, inf) — install math then yields the
            # always-valid lease non-coherent blocks carry
            mwts = jnp.zeros((NC,), jnp.int32)
            mrts = jnp.full((NC,), 2**30, jnp.int32)

        # ---------------- L2 probe + install math (Pallas hot path) -------
        # hit2u is UNGATED by need_l2 (not known yet).  Rows that turn out
        # not to reach L2 discard every derived value below: L2/L1 installs
        # are masked by l2_install/l1_install, both of which imply need_l2.
        (hit2_tag, hit2u, way2, rts2, l2_bwts, l2_brts, l2_ncts) = \
            S.tier_probe(st.l2, l2c, s2, addr, mwts, mrts)

        # HMG second-level probe at the home node for local misses
        if hmg:
            (hitH_tag, _, wayH, _, _, _, _) = \
                S.tier_probe(st.l2, home_l2, s2, addr, mwts, mrts)
            home_hit_u = hitH_tag & ~hit2u & remote
        else:
            wayH = way2
            home_hit_u = jnp.zeros_like(hit2u)

        # ---------------- response lease travelling up to L1 --------------
        # who reaches MM:  WT: all writes; WB: write misses (allocate) + read
        # misses.  HALCONE: writes always; read misses.  (ungated variant)
        if wb:
            need_mm_u = ~hit2u & ~home_hit_u
        else:
            need_mm_u = is_write | (~hit2u & ~home_hit_u)
        wts_from_l2 = jnp.where(hit2u | home_hit_u,
                                jnp.where(hit2u, st.l2.wts[l2c, s2, way2],
                                          st.l2.wts[home_l2, s2, wayH]),
                                mwts)
        rts_from_l2 = jnp.where(hit2u | home_hit_u,
                                jnp.where(hit2u, rts2,
                                          st.l2.rts[home_l2, s2, wayH]),
                                mrts)
        # lease hits keep their timestamps; misses and writes take the fresh
        # install (writes refresh the lease even on a hit)
        l2_new_wts = jnp.where(hit2u & ~is_write,
                               st.l2.wts[l2c, s2, way2], l2_bwts)
        l2_new_rts = jnp.where(hit2u & ~is_write, rts2, l2_brts)
        resp_wts = jnp.where(need_mm_u | is_write, l2_new_wts, wts_from_l2)
        resp_rts = jnp.where(need_mm_u | is_write, l2_new_rts, rts_from_l2)

        # ---------------- L1 probe + install math (Pallas hot path) -------
        (hit1_tag, hit1u, way1, _, l1_new_wts, l1_new_rts, l1_ncts) = \
            S.tier_probe(st.l1, cu_ids, s1, addr, resp_wts, resp_rts)
        l1_lease = protocol.Lease(l1_new_wts, l1_new_rts)
        l1_hit = hit1u & mem
        coh1 = hit1_tag & mem & (~l1_hit)
        need_l2 = (is_read & ~l1_hit) | is_write        # WT L1, writes descend

        # ---------------- gate the L2/MM outcomes -------------------------
        l2_hit = hit2u & need_l2
        coh2 = hit2_tag & need_l2 & (~l2_hit)
        home_hit = home_hit_u & need_l2
        if wb:
            need_mm = need_l2 & ~l2_hit & ~home_hit
        else:
            need_mm = is_write | (need_l2 & ~l2_hit & ~home_hit)

        # ---------------- TSU state updates -------------------------------
        if coherent:
            tsu = S.tsu_commit_scatter(st.tsu, hb, ts_set, wayT, addr,
                                       new_memts, need_mm, hitT)
        else:
            tsu = st.tsu

        # MM data versions: writes increment (scatter-add); then everyone
        # who reads MM sees the post-round version (same-tick semantics).
        wr_mask = is_write
        mm_ver = st.mm_ver.at[jnp.where(wr_mask, addr, n_addr - 1)].add(
            wr_mask.astype(jnp.int32))
        mm_val = mm_ver[addr]

        # ---------------- response values ----------------
        l1_val = st.l1.ver[cu_ids, s1, way1]
        l2_val = st.l2.ver[l2c, s2, way2]
        home_val = st.l2.ver[home_l2, s2, wayH]
        read_val = jnp.where(l1_hit, l1_val,
                             jnp.where(l2_hit, l2_val,
                                       jnp.where(home_hit, home_val, mm_val)))

        # value that lands in caches on a write: the post-write version
        fill_val = jnp.where(is_write, mm_val, read_val)

        # ---------------- install into L2 ----------------
        l2_install = need_l2 & (~l2_hit | is_write)
        v2 = S.victim(st.l2.tag, st.l2.lru, l2c, s2)
        w2i = jnp.where(l2_hit, way2, v2)
        dirty_evict = (st.l2_dirty[l2c, s2, w2i] &
                       (st.l2.tag[l2c, s2, w2i] != INVALID) & ~l2_hit &
                       l2_install) if wb else jnp.zeros_like(l2_install)
        w2s = jnp.where(l2_install, w2i, cfg.l2_ways)       # trash slot
        l2_tag = st.l2.tag.at[l2c, s2, w2s].set(
            jnp.where(l2_install, addr, INVALID))
        l2_ver = st.l2.ver.at[l2c, s2, w2s].set(fill_val)
        l2_rts = st.l2.rts.at[l2c, s2, w2s].set(l2_new_rts)
        l2_wts = st.l2.wts.at[l2c, s2, w2s].set(l2_new_wts)
        l2_lru_new = st.l2.lru.at[l2c, s2,
                                  jnp.where(need_l2, w2i, cfg.l2_ways)].set(rnd)
        l2_dirty = st.l2_dirty
        if wb:
            l2_dirty = l2_dirty.at[l2c, s2, w2s].set(is_write & l2_install)
            l2_dirty = l2_dirty.at[
                l2c, s2, jnp.where(l2_hit & is_write, way2,
                                   cfg.l2_ways)].set(True)
        if coherent:
            # max with 0 is a no-op for non-writers; the kernel's new_cts IS
            # cts_after_write(l2_cts, l2_bwts) for the write's fresh lease
            l2_cts = st.l2.cts.at[l2c].max(jnp.where(is_write, l2_ncts, 0))
        else:
            l2_cts = st.l2.cts

        # HMG: writer invalidates every sharer copy (VI), pays PCIe msgs
        inval_msgs = jnp.zeros((), jnp.float32)
        if hmg:
            shr = st.dir_sharers[addr]                       # [NC, G]
            n_shr = (shr.sum(-1) - shr[cu_ids, gpu_of]) * is_write
            inval_msgs = jnp.sum(n_shr.astype(jnp.float32))
            # membership test instead of an all-pairs compare: mark written
            # addresses in a dense table, gather it at every live tag.
            # (real addrs are < n_addr-1, so the trash row stays False)
            written = jnp.zeros((n_addr,), bool).at[
                jnp.where(is_write, addr, n_addr - 1)].max(is_write)
            safe_tag = jnp.where(l2_tag >= 0, l2_tag, n_addr - 1)
            kill = written[safe_tag]                         # [NL2, S2, W+1]
            # keep the writer's own copy
            own_keep = jnp.zeros_like(kill)
            own_keep = own_keep.at[l2c, s2, w2s].set(is_write)
            l2_tag = jnp.where(kill & ~own_keep, INVALID, l2_tag)
            new_shr = jnp.zeros_like(shr)
            new_shr = new_shr.at[cu_ids, gpu_of].set(is_write | is_read)
            dir_sharers = st.dir_sharers.at[
                jnp.where(is_write, addr, n_addr - 1)].min(
                    jnp.where(is_write[:, None], new_shr, True))
            dir_sharers = dir_sharers.at[
                jnp.where(mem, addr, n_addr - 1), gpu_of].set(True)
        else:
            dir_sharers = st.dir_sharers

        # ---------------- install into L1 ----------------
        l1_install = mem & (~l1_hit | is_write)
        v1 = S.victim(st.l1.tag, st.l1.lru, cu_ids, s1)
        w1i = jnp.where(hit1_tag, way1, v1)
        w1s = jnp.where(l1_install, w1i, cfg.l1_ways)
        l1_tag = st.l1.tag.at[cu_ids, s1, w1s].set(
            jnp.where(l1_install, addr, INVALID))
        l1_ver = st.l1.ver.at[cu_ids, s1, w1s].set(fill_val)
        l1_rts = st.l1.rts.at[cu_ids, s1, w1s].set(l1_lease.rts)
        l1_wts = st.l1.wts.at[cu_ids, s1, w1s].set(l1_lease.wts)
        l1_lru = st.l1.lru.at[cu_ids, s1,
                              jnp.where(mem, w1i, cfg.l1_ways)].set(rnd)
        if coherent:
            # the kernel's new_cts IS cts_after_write(l1_cts, l1_lease.wts)
            l1_cts = jnp.where(is_write, l1_ncts, st.l1.cts)
        else:
            l1_cts = st.l1.cts

        # fences: kernel boundary -> clocks jump to the global max
        if coherent:
            any_fence = jnp.any(is_fence)
            gmax = jnp.maximum(jnp.max(l1_cts), jnp.max(l2_cts))
            l1_cts = jnp.where(is_fence, gmax, l1_cts)
            l2_cts = jnp.where(any_fence, jnp.maximum(l2_cts, gmax), l2_cts)

        # ---------------- timing ----------------
        q_l2 = _queue_delay(l2c, need_l2, NL2, cfg.l2_service)
        mm_users = need_mm | dirty_evict if wb else need_mm
        q_mm = _queue_delay(hb, mm_users, NH, cfg.mm_service)
        pcie_hop = (remote & (need_l2 if not hmg else (need_mm | home_hit))) \
            if rdma else jnp.zeros_like(need_l2)
        q_pcie = _queue_delay(gpu_of, pcie_hop, G, cfg.pcie_service)
        # Reads block the issuing warp for the hierarchy round trip; a CU's
        # other wavefronts overlap ~mlp outstanding misses (latency hiding).
        read_lat = cfg.l1_lat + (
            need_l2 * (cfg.l2_lat + q_l2)
            + need_mm * (cfg.mm_lat + q_mm)
            + pcie_hop * (cfg.pcie_lat + q_pcie)) / cfg.mlp
        # Writes are POSTED: they consume bandwidth (queue terms above count
        # them) but don't stall the warp — except WB write-allocate fetches
        # and the dirty-eviction serialization the paper describes (§5.1).
        write_lat = cfg.l1_lat + q_l2
        if wb:
            write_lat = write_lat + (need_mm * (cfg.mm_lat + q_mm)
                + pcie_hop * (cfg.pcie_lat + q_pcie)) / cfg.mlp
        lat = jnp.where(is_read, read_lat,
                        jnp.where(is_write, write_lat, 0.0))
        if wb:
            lat = lat + dirty_evict * (cfg.mm_lat + q_mm) / cfg.mlp
        lat = lat + is_comp * addr.astype(jnp.float32)
        if hmg:
            lat = lat + is_write * (st.dir_sharers[addr].sum(-1)
                                    > 1) * cfg.pcie_lat
        time = st.time + jnp.where(mem | is_comp, lat, 0.0)

        # ---------------- counters ----------------
        f = lambda x: jnp.sum(x.astype(jnp.float32))
        ctr["reads"] += f(is_read)
        ctr["writes"] += f(is_write)
        ctr["l1_hits"] += f(l1_hit & is_read)
        ctr["l2_hits"] += f(l2_hit & need_l2)
        ctr["l1_to_l2"] += f(need_l2)
        ctr["l2_to_mm"] += f(need_mm) + (f(dirty_evict) if wb else 0.0)
        ctr["coh_miss_l1"] += f(coh1 & is_read) if coherent else 0.0
        ctr["coh_miss_l2"] += f(coh2 & is_read) if coherent else 0.0
        ctr["wb_evictions"] += f(dirty_evict) if wb else 0.0
        ctr["inval_msgs"] += inval_msgs if hmg else 0.0
        ctr["pcie_blocks"] += f(pcie_hop) if rdma else 0.0
        b12, b2m, big = S.link_bytes(
            f(need_l2), f(need_mm) + (f(dirty_evict) if wb else 0.0),
            f(pcie_hop) if rdma else 0.0, inval_msgs if hmg else 0.0)
        ctr["bytes_l1_l2"] += b12
        ctr["bytes_l2_mm"] += b2m
        ctr["bytes_inter_gpu"] += big

        new_st = SimState(
            l1=TierState(tag=l1_tag, wts=l1_wts, rts=l1_rts, ver=l1_ver,
                         lru=l1_lru, cts=l1_cts),
            l2=TierState(tag=l2_tag, wts=l2_wts, rts=l2_rts, ver=l2_ver,
                         lru=l2_lru_new, cts=l2_cts),
            l2_dirty=l2_dirty, tsu=tsu, mm_ver=mm_ver,
            dir_sharers=dir_sharers, time=time, ctr=ctr)
        if not with_log:
            return new_st, None
        # packed per-op result block, same [len(RES_FIELDS), lanes] layout
        # the fabric miss pass emits (core.state.RES_FIELDS): one int32
        # stack per round instead of a read-only log, so litmus/telemetry
        # callers see WHERE a request was served (level), which lease it
        # installed (wts/rts) and whether it reached main memory (mm_used).
        lvl = jnp.where(l1_hit, 0,
                        jnp.where(l2_hit, 1,
                                  jnp.where(home_hit, 2, 3)))
        i32 = lambda x: x.astype(jnp.int32)
        res = jnp.stack([
            i32(mem),                                        # found
            jnp.where(is_read, read_val,                     # version
                      jnp.where(is_write, mm_val, -1)),
            jnp.full((NC,), -1, jnp.int32),                  # gseq (n/a)
            jnp.where(is_read, lvl, -1),                     # level
            jnp.where(mem, l1_lease.wts, -1),                # wts
            jnp.where(mem, l1_lease.rts, -1),                # rts
            i32(need_mm),                                    # mm_used
        ])
        return new_st, res

    return round_step
