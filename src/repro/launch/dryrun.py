import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count="
                           + os.environ.get("DRYRUN_DEVICES", "512")).strip()

"""Multi-pod dry-run: lower + compile every (architecture x input shape) cell
on the production mesh and record memory/cost/collective analyses.

MUST be executed as its own process (the XLA flag above has to land before
jax initializes devices):

    PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-130m \
        --shape train_4k --mesh single

Artifacts land in benchmarks/artifacts/dryrun/<mesh>/<arch>__<shape>.json and
feed EXPERIMENTS.md §Dry-run / §Roofline via benchmarks/roofline.py.
"""
import argparse
import json
import pathlib
import time
import traceback

import jax
import numpy as np

from repro import configs as cfgs
from repro.launch import steps as S
from repro.launch import hloanalysis as H
from repro.launch import roofline as R
from repro.launch.mesh import make_production_mesh
from repro.models import applicable_shapes, model_spec
from repro.models.config import SHAPES
from repro.models.params import count_params, tree_paths

ART = pathlib.Path(__file__).resolve().parents[3] / "benchmarks" / "artifacts" / "dryrun"


def active_params(cfg) -> int:
    """Parameters touched per token: total minus the routed experts' share."""
    total = routed = 0
    for path, p in tree_paths(model_spec(cfg)):
        n = int(np.prod(p.shape))
        total += n
        if "/moe/w" in path:
            routed += n
    if cfg.n_experts:
        frac = cfg.top_k / cfg.n_experts
        return int(total - routed + routed * frac)
    return total


def mem_analysis_dict(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception as e:                     # backend without support
        return {"error": str(e)}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes", "peak_memory_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def run_cell(arch: str, shape_name: str, mesh_mode: str, force=False,
             variant: str = "base"):
    sub = mesh_mode if variant == "base" else f"{mesh_mode}-{variant}"
    out_path = ART / sub / f"{arch}__{shape_name}.json"
    if out_path.exists() and not force:
        print(f"[skip] {sub}/{arch}/{shape_name} (artifact exists)")
        return json.loads(out_path.read_text())
    cfg = cfgs.get(arch)
    cell = {c.name: c for c in SHAPES}[shape_name]
    if cell not in applicable_shapes(cfg):
        print(f"[n/a ] {arch}/{shape_name} not applicable (DESIGN.md)")
        return None
    mesh = make_production_mesh(multi_pod=(mesh_mode == "multi"))
    n_dev = mesh.devices.size
    args_variant = variant if variant.startswith("lease") else "base"
    fn, args, insh, outsh, donate = S.build_cell(cfg, cell, mesh, args_variant)

    t0 = time.time()
    jitted = jax.jit(fn, in_shardings=insh, out_shardings=outsh,
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = dict(compiled.cost_analysis() or {})
    mem = mem_analysis_dict(compiled)
    hlo = compiled.as_text()

    # cost_analysis() visits while bodies once -> useless under lax.scan;
    # the static analyzer walks the call graph with trip multipliers.
    hc = H.analyze(hlo, n_dev)
    flops, hbm = hc.flops, hc.hbm_bytes
    colls = {"per_kind": hc.coll_per_kind,
             "per_group_size": {str(k): v
                                for k, v in hc.coll_per_group.items()},
             "total_wire_bytes": hc.wire_bytes,
             "n_ops": hc.n_collectives,
             "trips": hc.trips}
    n_total = count_params(model_spec(cfg))
    n_active = active_params(cfg)
    mf = R.model_flops_for(cfg, cell, n_total, n_active)
    rl = R.roofline_terms(flops, hbm, colls["total_wire_bytes"], mf, n_dev)

    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_mode,
        "variant": variant,
        "n_devices": n_dev, "kind": cell.kind,
        "seq_len": cell.seq_len, "global_batch": cell.global_batch,
        "params_total": n_total, "params_active": n_active,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "cost_analysis": {k: float(v) for k, v in cost.items()
                          if np.isscalar(v)},
        "memory_analysis": mem,
        "collectives": colls,
        "roofline": {
            "flops_per_dev": rl.flops, "hbm_bytes_per_dev": rl.hbm_bytes,
            "wire_bytes_per_dev": rl.wire_bytes,
            "t_compute_s": rl.t_compute, "t_memory_s": rl.t_memory,
            "t_collective_s": rl.t_collective, "bottleneck": rl.bottleneck,
            "model_flops_per_dev": rl.model_flops,
            "useful_flop_ratio": rl.useful_ratio,
        },
    }
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(rec, indent=1))
    print(f"[ ok ] {sub}/{arch}/{shape_name}: compile {t_compile:.1f}s "
          f"flops/dev={flops:.3e} hbm/dev={hbm:.3e} "
          f"wire/dev={colls['total_wire_bytes']:.3e} "
          f"bottleneck={rl.bottleneck}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="base")
    args = ap.parse_args()
    archs = list(cfgs.ARCHS) if args.arch == "all" else [args.arch]
    shapes = ([c.name for c in SHAPES] if args.shape == "all"
              else [args.shape])
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    failures = []
    for mesh_mode in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    run_cell(arch, shape, mesh_mode, force=args.force,
                             variant=args.variant)
                except Exception:
                    failures.append((mesh_mode, arch, shape))
                    print(f"[FAIL] {mesh_mode}/{arch}/{shape}")
                    traceback.print_exc()
    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("dry-run complete")


if __name__ == "__main__":
    main()
