"""Continuous-batcher tests: firing semantics (deterministic via
``service_model``), pow2 bucketing, the continuous-beats-fixed goodput
property, ``form_waves``, and open-loop replay against a real fabric."""
import numpy as np
import pytest

from repro.coherence.fabric import ArrayFabric, FabricConfig
from repro.runtime import scheduler
from repro.runtime.loadgen import RequestTrace, synthesize
from repro.runtime.scheduler import (BatchPolicy, form_waves, pad_to_bucket,
                                     replay)


def mk_trace(t, kid=None, n_keys=8):
    t = np.asarray(t, np.float64)
    if kid is None:
        kid = np.arange(len(t)) % n_keys
    return RequestTrace(t=t, kid=np.asarray(kid, np.int32), n_keys=n_keys)


class FakeHandle:
    def __init__(self, keys):
        self.keys = keys

    def result(self):
        return [f"v:{k}" for k in self.keys]


class FakeBackend:
    """Records the call stream; instant service (virtual time modeled)."""

    def __init__(self):
        self.calls = []

    def read_batch_async(self, keys, replica=1):
        self.calls.append(("read", list(keys)))
        return FakeHandle(keys)

    def write_batch(self, items, replica=0):
        self.calls.append(("write", [k for k, _ in items]))

    def fence(self):
        self.calls.append(("fence",))


SVC = lambda b: 0.010          # flat 10 ms per fabric call, any size


# ------------------------------------------------------------------ policy
def test_policy_validation_and_bucketing():
    with pytest.raises(ValueError):
        BatchPolicy(mode="sometimes")
    with pytest.raises(ValueError):
        BatchPolicy(max_batch=0)
    p = BatchPolicy(max_batch=16, min_bucket=8)
    assert pad_to_bucket(list("abc"), p) == ["a", "b", "c", "a", "b", "c",
                                             "a", "b"]          # min bucket
    assert len(pad_to_bucket(list(range(9)), p)) == 16          # next pow2
    assert pad_to_bucket(list(range(8)), p) == list(range(8))   # exact fit
    assert pad_to_bucket([], p) == []
    raw = BatchPolicy(max_batch=16, bucket=False)
    assert pad_to_bucket(list("abc"), raw) == ["a", "b", "c"]


# ---------------------------------------------------------- firing semantics
def test_continuous_fires_partial_at_deadline():
    # 3 requests at t=0 + a straggler at t=1: the first wave fires partial
    # at the 5 ms deadline (the stream hasn't ended, so it must not wait
    # for the wave to fill); the straggler drains as a final fire the
    # moment the stream ends (no point waiting — nothing else can arrive)
    tr = mk_trace([0.0, 0.0, 0.0, 1.0])
    pol = BatchPolicy(mode="continuous", max_batch=8, max_wait_s=5e-3,
                      min_bucket=4)
    res = replay(FakeBackend(), tr, pol, service_model=SVC)
    assert res.fires == {"full": 0, "deadline": 1, "final": 1}
    assert res.batch_sizes == [3, 1] and res.padded_sizes == [4, 4]
    # the deadline wave waits exactly max_wait, then one dispatch quantum
    assert np.all(res.latency_s[:3] >= 5e-3 - 1e-9)
    assert np.all(res.latency_s <= 5e-3 + 2 * SVC(0) + 1e-9)


def test_continuous_drains_immediately_when_stream_ends():
    # all arrivals at t=0 and the stream is over: the partial wave fires
    # NOW as a final drain instead of burning the deadline budget
    res = replay(FakeBackend(), mk_trace([0.0, 0.0, 0.0]),
                 BatchPolicy(mode="continuous", max_batch=8,
                             max_wait_s=5e-3, min_bucket=4),
                 service_model=SVC)
    assert res.fires == {"full": 0, "deadline": 0, "final": 1}
    assert np.all(res.latency_s <= 2 * SVC(0) + 1e-9)   # no deadline wait


def test_fixed_fires_only_full_plus_final_partial():
    # 11 arrivals, max_batch=4 -> 2 full waves + 1 final partial of 3
    tr = mk_trace(np.linspace(0.0, 0.1, 11))
    pol = BatchPolicy(mode="fixed", max_batch=4, min_bucket=4)
    res = replay(FakeBackend(), tr, pol, service_model=SVC)
    assert res.fires == {"full": 2, "deadline": 0, "final": 1}
    assert res.batch_sizes == [4, 4, 3]
    assert res.padded_sizes == [4, 4, 4]
    assert res.n_requests == 11 and not np.isnan(res.latency_s).any()
    assert np.all(res.latency_s >= 0)


def test_fixed_starves_until_wave_fills():
    # one request, then a 1 s gap before the wave-filling arrivals: under
    # fixed it waits for the fill; continuous releases it at the deadline
    t = [0.0, 1.0, 1.0, 1.0]
    pol_kw = dict(max_batch=4, max_wait_s=5e-3, min_bucket=4)
    fixed = replay(FakeBackend(), mk_trace(t),
                   BatchPolicy(mode="fixed", **pol_kw), service_model=SVC)
    cont = replay(FakeBackend(), mk_trace(t),
                  BatchPolicy(mode="continuous", **pol_kw),
                  service_model=SVC)
    assert fixed.latency_s[0] >= 1.0          # starved a full second
    assert cont.latency_s[0] < 0.05           # released by the deadline
    assert fixed.fires["full"] == 1 and cont.fires["deadline"] >= 1


def test_bucket_pads_cycle_wave_own_keys():
    tr = mk_trace([0.0, 0.0, 0.0], kid=[5, 6, 7], n_keys=8)
    pol = BatchPolicy(max_batch=8, max_wait_s=1e-3, min_bucket=8)
    be = FakeBackend()
    res = replay(be, tr, pol, service_model=SVC)
    reads = [c for c in be.calls if c[0] == "read"]
    assert len(reads) == 1
    # pads are drawn from the wave's own keys — no new keys introduced
    assert reads[0][1] == [f"prefix/{k}" for k in
                           [5, 6, 7, 5, 6, 7, 5, 6]]
    assert res.events == [("read", [5, 6, 7, 5, 6, 7, 5, 6])]


def test_republish_storm_precedes_wave_and_fences():
    tr = mk_trace(np.zeros(4), kid=[0, 1, 2, 3], n_keys=8)
    pol = BatchPolicy(max_batch=4, min_bucket=4)
    be = FakeBackend()
    res = replay(be, tr, pol, republish_every=1, republish_n=3,
                 service_model=SVC)
    kinds = [c[0] for c in be.calls]
    assert kinds == ["write", "fence", "read"]
    assert [e[0] for e in res.events] == ["write", "fence", "read"]
    assert res.events[0][1] == [0, 1, 2]      # round-robin republish slice
    assert res.walls["republish_s"] > 0


# ------------------------------------------------- continuous beats fixed
def test_continuous_goodput_dominates_fixed_on_trickle():
    """The headline property, provable under the deterministic service
    model: on a trickle (arrival gap >> service), fixed-size waves starve
    the batch while continuous releases at the deadline."""
    tr = synthesize(200, 16, process="poisson", rate=100.0, seed=3)
    kw = dict(max_batch=32, max_wait_s=20e-3, min_bucket=8)
    cont = replay(FakeBackend(), tr, BatchPolicy(mode="continuous", **kw),
                  service_model=SVC)
    fixed = replay(FakeBackend(), tr, BatchPolicy(mode="fixed", **kw),
                   service_model=SVC)
    slo = 50e-3                                # deadline + a few quanta
    ok_c, att_c = cont.goodput(slo)
    ok_f, att_f = fixed.goodput(slo)
    assert ok_c + ok_f == round(att_c * 200) + round(att_f * 200)
    assert att_c > att_f                       # strictly better here
    assert att_c > 0.9
    # same request count either way; nothing lost
    assert cont.n_requests == fixed.n_requests == 200


# ---------------------------------------------------------------- form_waves
def test_form_waves_matches_replay_semantics():
    items = list("abcdefghijk")
    t = np.linspace(0.0, 0.1, len(items))
    fixed = form_waves(t, items, BatchPolicy(mode="fixed", max_batch=4))
    assert fixed == [list("abcd"), list("efgh"), list("ijk")]
    # continuous with a huge deadline behaves like fixed
    cont = form_waves(t, items, BatchPolicy(max_batch=4, max_wait_s=10.0))
    assert cont == fixed
    # continuous with a tiny deadline fires singletons on a slow trickle
    slow = form_waves(np.arange(5) * 1.0, list(range(5)),
                      BatchPolicy(max_batch=4, max_wait_s=1e-3))
    assert slow == [[0], [1], [2], [3], [4]]
    assert form_waves([], [], BatchPolicy()) == []
    with pytest.raises(ValueError):
        form_waves([0.0], [], BatchPolicy())
    with pytest.raises(ValueError):
        form_waves([1.0, 0.5], ["a", "b"], BatchPolicy())


def test_form_waves_preserves_order_and_items():
    tr = synthesize(300, 8, process="burst", rate=50.0, seed=1)
    waves = form_waves(tr.t, list(range(300)),
                       BatchPolicy(max_batch=16, max_wait_s=10e-3))
    flat = [x for w in waves for x in w]
    assert flat == list(range(300))            # order kept, nothing dropped
    assert all(0 < len(w) <= 16 for w in waves)


# ----------------------------------------------------------- real fabric
SMALL = dict(n_shards=2, rd_lease=16, wr_lease=4, replica_sets=16,
             replica_ways=4, shared_sets=32, shared_ways=4)


def test_replay_against_array_fabric():
    """End-to-end open-loop replay on a real single-device fabric: values
    resolve correctly, stats move, and the ordering contract holds."""
    fab = ArrayFabric(FabricConfig(**SMALL), n_nodes=1, replicas_per_node=2)
    n_keys = 8
    fab.write_batch([(f"prefix/{k}", f"v@init") for k in range(n_keys)],
                    replica=0)
    fab.fence()
    tr = synthesize(60, n_keys, process="poisson", rate=500.0, seed=6)
    pol = BatchPolicy(max_batch=8, max_wait_s=2e-3, min_bucket=8)
    res = replay(fab, tr, pol, republish_every=4, republish_n=4)
    assert res.n_requests == 60
    assert np.all(res.latency_s >= 0) and res.t_end > 0
    assert sum(res.batch_sizes) == 60
    assert all(p in (8, 16) for p in res.padded_sizes)
    assert res.fires["full"] + res.fires["deadline"] + res.fires["final"] \
        == len(res.batch_sizes)
    st = fab.stats()
    assert st["reads"] >= sum(res.padded_sizes)
    assert st["fast_read_batches"] >= 0 and st["write_batches"] > 0
    # the event stream replays the same reads the fabric saw
    n_read_rows = sum(len(e[1]) for e in res.events if e[0] == "read")
    assert n_read_rows == sum(res.padded_sizes)
