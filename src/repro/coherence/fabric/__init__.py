"""Coherence fabric: the sharded TSU service behind every lease in the repo.

Layout (DESIGN.md §3):
  tsu.py    — TSUShard / TSUFabric: the MM+TSU authority, key-hash sharded
  cache.py  — ReplicaCache over SharedCache: the host L1-over-L2 client tiers
  writeq.py — WriteQueue: bounded posted write-throughs + fence
  stats.py  — FabricStats: the engine.COUNTERS-compatible telemetry block

`repro.coherence.kv_lease` (serving) and `repro.coherence.lease_sync`
(training) are thin adapters over this package; the hierarchy simulator
(`repro.core.engine`) is the same protocol run under a timing model.
"""
from repro.coherence.fabric.cache import ReplicaCache, SharedCache  # noqa: F401
from repro.coherence.fabric.stats import FabricStats  # noqa: F401
from repro.coherence.fabric.tsu import (FabricConfig, LeaseGrant,  # noqa: F401
                                        TSUFabric, TSUShard, stable_hash)
from repro.coherence.fabric.writeq import WriteQueue  # noqa: F401
