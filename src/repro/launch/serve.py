"""Serving launcher: batched requests through the lease-coherent server.

    PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --requests 8

The server obtains every prefix-KV lease from the array-native coherence
fabric (--tsu-shards TSU shards; mesh-placed on devices via
``ShardedArrayFabric`` when more than one device is visible) via ONE
batched probe per serve call — the same backend (and the same `core.state`
transition rules) the trainer and benchmarks use.
"""
import argparse
import json

import jax
import numpy as np

from repro import configs as cfgs
from repro.coherence.fabric import FabricConfig, default_fabric
from repro.models import init_model
from repro.runtime.server import Request, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(cfgs.ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tsu-shards", type=int, default=4)
    ap.add_argument("--rd-lease", type=int, default=8)
    ap.add_argument("--wr-lease", type=int, default=4)
    args = ap.parse_args()

    cfg = cfgs.SMOKE[args.arch]            # serving demo runs the smoke cfg
    params = init_model(cfg, jax.random.PRNGKey(0))
    # mesh-placed TSU shards when this host has >1 device (DESIGN.md §8)
    fabric = default_fabric(FabricConfig(n_shards=args.tsu_shards,
                                         rd_lease=args.rd_lease,
                                         wr_lease=args.wr_lease))
    if getattr(fabric, "mesh", None) is not None:
        print(f"fabric mesh: {fabric.mesh} "
              f"({args.tsu_shards} shards on "
              f"{fabric.mesh.devices.size} devices)")
    srv = Server(cfg, params, batch_size=args.batch,
                 max_len=args.prompt_len + args.max_new + 8, fabric=fabric)
    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.requests):
        # half the requests share a prompt -> exercises the lease cache
        seed = i % max(args.requests // 2, 1)
        prompt = np.random.default_rng(seed).integers(
            2, cfg.vocab, args.prompt_len).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, max_new=args.max_new))
    # two waves: wave 1 prefills under one batched probe + one batched
    # write-through; wave 2's identical prefixes ride the live leases
    out = srv.serve(reqs[:len(reqs) // 2])
    out.update(srv.serve(reqs[len(reqs) // 2:]))
    for rid in sorted(out):
        print(f"req {rid}: {list(out[rid])}")
    print("lease-cache stats:", srv.cache_stats)
    print("fabric stats:", json.dumps(srv.fabric_stats))


if __name__ == "__main__":
    main()
