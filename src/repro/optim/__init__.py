from repro.optim.adamw import (AdamWConfig, TrainState, abstract_state,  # noqa: F401
                               apply_updates, global_norm, init_state,
                               state_shardings)
