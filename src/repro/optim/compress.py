"""Int8 gradient compression with error feedback.

Wire-format trick for the collective roofline term: gradients cross the ICI
as int8 (4x fewer bytes than f32, 2x fewer than bf16); the quantization error
is fed back into the next step's gradient so the optimizer sees an unbiased
long-run signal (standard EF-SGD result).

``compressed_psum``: shard_map ring — reduce-scatter in int8 chunks (local
dequant-accumulate in f32) then all-gather the int8 result.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro.sharding as sharding


def quantize(x, axis=None) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x), axis=axis, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def ef_compress(grad, error):
    """Error feedback: returns (decompressed_grad, new_error)."""
    g = grad.astype(jnp.float32) + error
    q, s = quantize(g)
    deq = dequantize(q, s)
    return deq.astype(grad.dtype), g - deq


def compressed_psum(x, axis_name: str, n: int):
    """Inside shard_map: int8-wire psum of a replicated-per-shard value.

    reduce-scatter(int8) -> local f32 accumulate -> all-gather(int8).
    Wire bytes: 2 * (n-1)/n * |x|/4 vs f32 all-reduce's 2 * (n-1)/n * |x|."""
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % n
    flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    q, s = quantize(chunks, axis=1)                  # per-chunk scales
    # exchange: every shard receives chunk i from all peers
    qx = jax.lax.all_to_all(q[None], axis_name, 0, 0, tiled=False)[:, 0]
    sx = jax.lax.all_to_all(s[None], axis_name, 0, 0, tiled=False)[:, 0]
    local_sum = jnp.sum(dequantize(qx, sx), axis=0)  # [chunk]
    q2, s2 = quantize(local_sum[None], axis=1)
    qg = jax.lax.all_gather(q2[0], axis_name)        # [n, chunk] int8
    sg = jax.lax.all_gather(s2[0], axis_name)
    out = dequantize(qg, sg.reshape(n, 1)).reshape(-1)
    out = out[:x.size] if pad else out
    return out.reshape(x.shape).astype(x.dtype)


def make_compressed_allreduce(mesh, dp_axes=("data",)):
    """jit-able f32->int8-wire all-reduce over the data axes via shard_map."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n = 1
    for a in dp_axes:
        n *= sizes[a]
    axis = dp_axes[0] if len(dp_axes) == 1 else dp_axes

    def ar(x):
        def inner(xs):
            return compressed_psum(xs, axis, n)
        return sharding.shard_map(inner, mesh=mesh, in_specs=P(),
                                  out_specs=P(), axis_names=set(dp_axes),
                                  check_vma=False)(x)

    return ar
