"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import protocol


def attention_ref(q, k, v, *, causal=True, window=0, kv_len=None):
    """q: [B,Sq,Hq,D]; k,v: [B,Sk,Hkv,D] — plain softmax attention."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    qpk = Hq // Hkv
    qr = q.reshape(B, Sq, Hkv, qpk, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(jnp.float32)) * D ** -0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.zeros((Sq, Sk), jnp.float32)
    if causal:
        mask = jnp.where(kpos > qpos, -1e30, mask)
    if window:
        mask = jnp.where(qpos - kpos >= window, -1e30, mask)
    if kv_len is not None:
        mask = jnp.where(kpos >= kv_len, -1e30, mask)
    p = jax.nn.softmax(s + mask, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def ssd_chunk_ref(x, dt, A, Bc, Cc):
    """Intra-chunk SSD reference for ONE chunk.

    x: [Q,P]; dt: [Q]; A: scalar; Bc, Cc: [Q,N].
    Returns (y_intra [Q,P], chunk_state [N,P], cum [Q])."""
    dA = dt * A
    cum = jnp.cumsum(dA)
    li = cum[:, None] - cum[None, :]
    L = jnp.exp(jnp.where(jnp.tril(jnp.ones_like(li, bool)), li, -jnp.inf))
    cb = Cc.astype(jnp.float32) @ Bc.astype(jnp.float32).T      # [Q,Q]
    scores = cb * L * dt[None, :]
    y = scores @ x.astype(jnp.float32)
    decay_out = jnp.exp(cum[-1] - cum)
    state = (Bc.astype(jnp.float32) * (dt * decay_out)[:, None]).T \
        @ x.astype(jnp.float32)                                  # [N,P]
    return y.astype(x.dtype), state, cum


def rmsnorm_ref(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def lease_probe_ref(tag_rows, rts_rows, cts, addr, mwts, mrts):
    """HALCONE probe+install math (engine hot loop) over gathered set rows.

    tag_rows/rts_rows: [N,W]; cts/addr/mwts/mrts: [N].
    Returns (tag_hit, hit, way, row_rts, new_wts, new_rts, new_cts) —
    the same seven outputs as kernels.lease_probe, derived exclusively
    from core.protocol so the kernel's math is pinned to Algorithms 1-5."""
    eq = tag_rows == addr[:, None]
    tag_hit = eq.any(-1)
    way = jnp.argmax(eq, -1).astype(jnp.int32)
    rts = jnp.take_along_axis(rts_rows, way[:, None], 1)[:, 0]
    row_rts = jnp.where(tag_hit, rts, 0)
    hit = tag_hit & protocol.valid(cts, row_rts)
    lease = protocol.install(cts, mwts, mrts)
    new_cts = protocol.cts_after_write(cts, lease.wts)
    return tag_hit, hit, way, row_rts, lease.wts, lease.rts, new_cts
