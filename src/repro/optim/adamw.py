"""AdamW with global-norm clipping, cosine schedule, and policy-controlled
moment dtype (bf16 moments for the >=200B archs — see DESIGN.md)."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class TrainState(NamedTuple):
    params: Any
    m: Any
    v: Any
    step: jnp.ndarray


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000


def init_state(params, moment_dtype=jnp.float32) -> TrainState:
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return TrainState(params=params,
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params),
                      step=jnp.zeros((), jnp.int32))


def abstract_state(params, moment_dtype=jnp.float32) -> TrainState:
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, moment_dtype)
    return TrainState(params=params,
                      m=jax.tree.map(sds, params),
                      v=jax.tree.map(sds, params),
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def state_shardings(param_shardings, mesh) -> TrainState:
    from jax.sharding import NamedSharding, PartitionSpec
    return TrainState(params=param_shardings,
                      m=param_shardings, v=param_shardings,
                      step=NamedSharding(mesh, PartitionSpec()))


def schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, state: TrainState, grads) -> TrainState:
    step = state.step + 1
    lr = schedule(cfg, step)
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gn + 1e-9))

    def upd(p, m, v, g):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * cfg.b1 + g * (1 - cfg.b1)
        v32 = v.astype(jnp.float32) * cfg.b2 + g * g * (1 - cfg.b2)
        mh = m32 / (1 - cfg.b1 ** step)
        vh = v32 / (1 - cfg.b2 ** step)
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32 * (p.ndim > 1))
        return p32.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

    out = jax.tree.map(upd, state.params, state.m, state.v, grads)
    params = jax.tree.map(lambda t: t[0], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return TrainState(params=params, m=m, v=v, step=step)
