from repro.models.config import ModelConfig, Policy, ShapeCell, SHAPES, applicable_shapes  # noqa: F401
from repro.models.model import (  # noqa: F401
    abstract_cache, abstract_model, cache_spec, decode_step, forward,
    init_cache, init_model, loss_fn, model_shardings, model_spec, prefill,
)
