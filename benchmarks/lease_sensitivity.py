"""§5.4: sensitivity to (RdLease, WrLease) on the coherence-heavy Xtreme
suite.  Paper: widening |RdLease-WrLease| from 5 to 10 costs up to ~3%.

Leases are DATA fields of the config pytree (sysconfig), so all six pairs
share one static structure and run as a single 6-wide config-vmap group —
the purest form of the batched sweep's config axis (DESIGN.md §5)."""
from benchmarks import common
from benchmarks.common import cached, emit
from repro.core.sysconfig import sm_wt_halcone
from repro.core.traces import XtremeSpec, xtreme

PAIRS = [(2, 10), (10, 2), (5, 10), (10, 5), (20, 10), (10, 20)]
SYS = dict(n_gpus=4, cus_per_gpu=32)


def run_all(force=False):
    def compute():
        spec = XtremeSpec(3, 24, 6)
        base = sm_wt_halcone(**SYS)
        named = {"xtreme3_192KB": xtreme(base, spec)}
        cfgs = [(f"rd{rd}_wr{wr}",
                 sm_wt_halcone(rd_lease=rd, wr_lease=wr, **SYS))
                for rd, wr in PAIRS]
        out = common.sweep(cfgs, named, measure_sequential=False)
        res = {name: {"cycles": out["cycles"][ci][0]}
               for ci, name in enumerate(out["configs"])}
        res["wall"] = out["wall"]
        return res

    return cached("lease_sensitivity", compute, force, script=__file__)


def main(force=False):
    data = run_all(force)
    points = {k: v for k, v in data.items() if k != "wall"}
    best = min(v["cycles"] for v in points.values())
    for k, v in points.items():
        emit(f"lease/{k}", 0.0, f"vs_best={v['cycles']/best - 1:+.2%}")
    return data


if __name__ == "__main__":
    main()
