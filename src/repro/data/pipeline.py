"""Deterministic synthetic data pipeline.

Seeded, shardable, restartable: batch `i` is a pure function of (seed, i), so
a restarted job resumes mid-epoch with no state beyond the step counter
(write-through semantics — the same property HALCONE gets from WT caches).
Per-host slicing matches the ("pod","data") batch sharding.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from repro.models.config import ModelConfig
from repro.runtime.loadgen import bounded_zipf


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    seed: int = 1234
    n_docs: int = 4096          # synthetic corpus size
    mean_doc_len: int = 512
    host_index: int = 0
    host_count: int = 1


class SyntheticLM:
    """Zipf-distributed token stream with document structure (BOS=0, EOS=1).

    Statistically language-like enough to drive loss-goes-down training runs
    and data-pipeline tests without an external corpus.
    """

    def __init__(self, cfg: ModelConfig, dcfg: DataConfig):
        self.cfg = cfg
        self.dcfg = dcfg
        assert dcfg.global_batch % dcfg.host_count == 0
        self.local_batch = dcfg.global_batch // dcfg.host_count

    def _doc(self, doc_id: int) -> np.ndarray:
        rng = np.random.default_rng((self.dcfg.seed, doc_id))
        n = max(8, int(rng.exponential(self.dcfg.mean_doc_len)))
        # Zipf body tokens in [2, vocab); simple bigram structure for signal.
        # bounded_zipf samples the truncated law exactly — numpy's
        # rng.zipf % n wraps the unbounded tail and flattens the skew.
        base = bounded_zipf(self.cfg.vocab - 2, 1.3).sample(rng, size=n) + 2
        shift = (doc_id * 7919) % (self.cfg.vocab - 2) + 2
        base[1::2] = (base[:-1:2] + shift) % (self.cfg.vocab - 2) + 2
        return np.concatenate([[0], base, [1]]).astype(np.int32)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.local_batch, self.dcfg.seq_len
        out = np.empty((B, S), np.int32)
        for b in range(B):
            row = self.dcfg.host_index * B + b
            rng = np.random.default_rng((self.dcfg.seed, step, row))
            doc = int(rng.integers(self.dcfg.n_docs))
            buf = self._doc(doc)
            while len(buf) < S:
                doc = (doc + 1) % self.dcfg.n_docs
                buf = np.concatenate([buf, self._doc(doc)])
            start = int(rng.integers(max(1, len(buf) - S)))
            out[b] = buf[start:start + S]
        batch = {"tokens": out}
        if self.cfg.frontend == "audio":
            rng = np.random.default_rng((self.dcfg.seed, step, 999))
            batch = {"frames": rng.standard_normal(
                         (B, S, self.cfg.d_frontend)).astype(np.float32),
                     "labels": out % self.cfg.vocab}
        elif self.cfg.frontend == "vision":
            rng = np.random.default_rng((self.dcfg.seed, step, 998))
            batch["patches"] = (rng.standard_normal(
                (B, self.cfg.n_patch_tokens, self.cfg.d_model))
                .astype(np.float32) * 0.02)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
