"""Batched serving with the lease-coherent prefix cache: the server issues
ONE batched lease probe per serve call against the array-native fabric;
repeated prompts are served under a live lease instead of re-prefilling
(HALCONE semantics — no invalidation traffic, ever).

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro import configs as cfgs
from repro.models import init_model
from repro.runtime.server import Request, Server


def main():
    cfg = cfgs.SMOKE["smollm-360m"]
    params = init_model(cfg, jax.random.PRNGKey(0))
    srv = Server(cfg, params, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab, 12).astype(np.int32)
    reqs = [Request(rid=i, prompt=prompt, max_new=6) for i in range(6)]
    # round 1: the unique prefix misses once, is prefilled, and its
    # write-through posts the lease (one batched probe + one batched put)
    out = srv.serve(reqs)
    # round 2: the same prefix is served straight from the lease cache
    out = srv.serve(reqs)
    for rid in sorted(out):
        print(f"request {rid}: {list(out[rid])}")
    print("prefix-cache stats:", srv.cache_stats)
    print("fabric stats:", {k: v for k, v in srv.fabric_stats.items() if v})
    assert srv.cache_stats["hits"] >= 1
    # inval_msgs is 0 BY CONSTRUCTION in the fabric (the paper's design:
    # no invalidation path exists to send one) — reported, not asserted
    print("OK: repeated prompt batches served from the lease cache")


if __name__ == "__main__":
    main()
