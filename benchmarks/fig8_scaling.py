"""Fig 8: strong-scaling of SM-WT-C-HALCONE with GPU count (1..16, 32 CUs)
and CU count (32/48/64 at 4 GPUs).  Paper: 1.76/2.74/4.05/5.43x for
2/4/8/16 GPUs; 1.12/1.24x for 48/64 CUs.

Each scaling point is one batched sweep (the 11-benchmark axis vmapped in a
single jit, DESIGN.md §5); points differ in CU-grid shape so they compile
separately by construction."""
import argparse

import numpy as np

from benchmarks import common
from benchmarks.common import cached, emit
from repro.core import traces
from repro.core.sysconfig import sm_wt_halcone

BASE_ROUNDS = 1024          # at the 4x32 reference point
BENCHES = list(traces.STANDARD)

# Amdahl serial fraction: dependent-kernel chains + launch overhead that do
# not parallelize (why atax/bicg/mp/rl saturate beyond 4 GPUs in the paper;
# the simulator covers the parallel part only).  Calibrated to Fig 8.
SERIAL_FRAC = {"atax": 0.40, "bicg": 0.40, "mp": 0.45, "rl": 0.45,
               "bfs": 0.10, "bs": 0.08, "fws": 0.06, "fir": 0.04,
               "aes": 0.03, "mm": 0.02, "conv": 0.02}


def amdahl(speedup_sim: float, frac: float) -> float:
    return 1.0 / (frac + (1.0 - frac) / max(speedup_sim, 1e-9))


def _point(cfg, rounds):
    """One scaling point: all 11 benchmarks batched through one jit."""
    named = {b: traces.standard_trace(cfg, traces.STANDARD[b], rounds)
             for b in BENCHES}
    out = common.sweep([(cfg.name, cfg)], named, measure_sequential=False)
    return {"benchmarks": out["benchmarks"],
            "cycles": out["cycles"][0],
            "wall": out["wall"]}


def run_gpu(force=False):
    def compute():
        out = {}
        for g in (1, 2, 4, 8, 16):
            cfg = sm_wt_halcone(n_gpus=g, cus_per_gpu=32)
            rounds = max(128, BASE_ROUNDS * 4 // g)
            out[str(g)] = _point(cfg, rounds)
        return out

    return cached("fig8_gpu_scaling", compute, force, script=__file__)


def run_cu(force=False):
    def compute():
        out = {}
        for cu in (32, 48, 64):
            cfg = sm_wt_halcone(n_gpus=4, cus_per_gpu=cu)
            rounds = max(128, BASE_ROUNDS * 32 // cu)
            out[str(cu)] = _point(cfg, rounds)
        return out

    return cached("fig8_cu_scaling", compute, force, script=__file__)


def _cycles(point, bench):
    return point["cycles"][point["benchmarks"].index(bench)]


def main(axis="both", force=False):
    data = {}
    if axis in ("gpu", "both"):
        data["gpu"] = run_gpu(force)
        for g in (2, 4, 8, 16):
            sp = [amdahl(_cycles(data["gpu"]["1"], b)
                         / _cycles(data["gpu"][str(g)], b),
                         SERIAL_FRAC[b]) for b in BENCHES]
            emit(f"fig8a/gpus{g}", 0.0,
                 f"speedup={float(np.exp(np.mean(np.log(sp)))):.2f}x")
    if axis in ("cu", "both"):
        data["cu"] = run_cu(force)
        for cu in (48, 64):
            sp = [amdahl(_cycles(data["cu"]["32"], b)
                         / _cycles(data["cu"][str(cu)], b),
                         SERIAL_FRAC[b]) for b in BENCHES]
            emit(f"fig8bc/cus{cu}", 0.0,
                 f"speedup={float(np.exp(np.mean(np.log(sp)))):.2f}x")
    return data


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--axis", default="both")
    args = ap.parse_args()
    main(axis=args.axis)
