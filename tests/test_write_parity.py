"""Write-path parity suite: the batched write pass (DESIGN.md §11) is
BIT-IDENTICAL to the host-object oracle and to the per-op scan schedule
on randomized write/fence storms.

``write_batch`` is the publish-storm entry point: a batch of posted
write-throughs that fill the bounded write queue, drain in FIFO order
whenever more than ``max_in_flight`` are outstanding, and fence with the
kernel-boundary clock jump.  Under ``pipeline="batched"`` the array
fabric serves the whole storm as a few vectorized conflict-free rounds —
owner-grouped TSU write-through grants, prefix-sum drain sequencing over
the ring queue, ONE packed collective per batch on the sharded fabric —
and every observable must match the oracle exactly: the ordered MM grant
log, the full FabricStats block (including the Fig-10 per-link byte
counters and the ``write_batches`` boundary count), each replica's
mirror counters, per-key ``memts``, and the full device state of
batched-vs-scan.

The storms are adversarial by construction: skewed (hot-head) keys and
duplicate keys inside one batch force conflict rounds; batches larger
than ``max_in_flight`` force queue fill->drain inside the pass; near-
TS_MAX write leases force the 16-bit overflow reinit and tiny TSU tables
force victim evictions INSIDE the batched write-through.  A hypothesis
layer fuzzes the same property when hypothesis is installed; a jaxpr pin
asserts a 512-op publish storm issues exactly ONE packed collective
(vs one per scan step); and the forced-8-device harness re-runs the
storm parity on a real multi-device mesh (same subprocess idiom as
tests/test_fabric_parity.py).
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.coherence.fabric import (ArrayFabric, FabricConfig, HostFabric,
                                    Op, ShardedArrayFabric)
from repro.core.state import BLOCK_BYTES

from test_fabric_parity import (KEYS, MEDIUM, OVERFLOW, SMALL,
                                assert_state_equal, build_pair, build_triple,
                                random_trace)

# drains spread across shards (at most one TSU write per shard per round)
# while the small queue forces fill->drain inside every storm: the batched
# write pass runs REAL multi-op rounds here instead of the fallback
WRITEHOT = dict(n_shards=4, rd_lease=8, wr_lease=20000, tsu_capacity=2,
                shared_sets=4, shared_ways=2, replica_sets=4,
                replica_ways=2, max_in_flight=2)


def _drive_write_storms(backends, seed, n_calls=8, max_batch=12):
    """Randomized publish storms on every backend in lock-step: skewed
    (hot-head) keys with duplicates inside a batch (conflict rounds),
    random replicas and write leases (30000 forces 16-bit wraps), batches
    larger than ``max_in_flight`` (queue fill->drain inside the pass),
    interleaved reads, and fences over a non-empty queue (drain + clock
    jump).  Returns the per-call read results for comparison."""
    rng = np.random.default_rng(seed)
    outs = [[] for _ in backends]
    for c in range(n_calls):
        rep = int(rng.integers(backends[0].n_replicas))
        wl = (None, 1, 30000)[int(rng.integers(3))]
        n = int(rng.integers(1, max_batch + 1))
        ks = [KEYS[int(rng.integers(2 if rng.random() < 0.5 else len(KEYS)))]
              for _ in range(n)]
        items = [(k, f"s{seed}.{c}.{i}") for i, k in enumerate(ks)]
        for b in backends:
            b.write_batch(items, replica=rep, wr_lease=wl)
        rk = KEYS[int(rng.integers(len(KEYS)))]
        rr = int(rng.integers(backends[0].n_replicas))
        for o, b in zip(outs, backends):
            o.append(b.read(rk, replica=rr))
        if c % 3 == 2:
            for b in backends:
                b.fence()
    return outs


def assert_write_equivalent(host, *arrays):
    """Every observable of the write path, against the oracle: stats
    (incl. Fig-10 bytes + write_batches), ordered grant log, replica
    mirrors, memts — plus the Fig-10 invariants themselves."""
    for arr in arrays:
        assert host.stats() == arr.stats(), "FabricStats diverged"
        assert list(host.grant_log) == list(arr.grant_log), \
            "MM grant logs diverged"
        for r in range(host.n_replicas):
            assert host.replica_stats(r) == arr.replica_stats(r), \
                f"replica {r} mirror counters diverged"
        for k in KEYS:
            assert host.memts(k) == arr.memts(k), f"memts({k!r}) diverged"
    st = host.stats()
    assert st["bytes_l1_l2"] == st["l1_to_l2"] * BLOCK_BYTES
    assert st["bytes_l2_mm"] == st["l2_to_mm"] * BLOCK_BYTES
    assert st["bytes_inter_gpu"] == st["pcie_blocks"] * BLOCK_BYTES
    assert st["inval_msgs"] == 0                # the paper's claim


@pytest.mark.parametrize("seed,cfg_kw", [(0, SMALL), (1, SMALL), (2, SMALL),
                                         (0, MEDIUM), (1, MEDIUM),
                                         (0, WRITEHOT)])
def test_write_storm_parity(seed, cfg_kw):
    """The tentpole pin: randomized write/fence storms are bit-identical
    across host oracle / batched write pass / scan pipeline — warm trace
    first so storms land on dirty tiers and non-empty queues.  SMALL
    mostly stresses the conflict-round fallback; MEDIUM and WRITEHOT run
    real vectorized rounds — both paths must stay exact."""
    host, batched, scan = build_triple(cfg_kw)
    warm = random_trace(np.random.default_rng(seed + 100), 120, 4)
    for b in (host, batched, scan):
        b.apply(warm)
    oh, ob, os_ = _drive_write_storms((host, batched, scan), seed)
    assert oh == ob, "batched write pass diverged from the host oracle"
    assert oh == os_, "scan pipeline diverged from the host oracle"
    assert_write_equivalent(host, batched, scan)
    assert host.stats()["write_batches"] >= 8
    assert host.stats()["write_throughs"] > 0, "storms never drained"
    assert_state_equal(batched, scan)


def test_write_pass_queue_fill_then_drain():
    """Deterministic queue bookkeeping: 8 posted writes through a 2-deep
    queue drain exactly 6 inside the batch; the fence drains the 2 still
    queued before the clock jump — counted identically everywhere."""
    host, batched, scan = build_triple(SMALL)       # max_in_flight=2
    items = [(k, f"{k}@q") for k in KEYS]
    for b in (host, batched, scan):
        b.write_batch(items, replica=0)
    assert host.stats()["write_throughs"] == 6
    assert_write_equivalent(host, batched, scan)
    for b in (host, batched, scan):
        b.fence()
    assert host.stats()["write_throughs"] == 8
    assert host.stats()["fences"] == 1
    assert_write_equivalent(host, batched, scan)
    assert_state_equal(batched, scan)


def test_write_pass_overflow_reinit_and_tsu_eviction():
    """Forced 16-bit overflow reinits + TSU victim evictions INSIDE the
    batched write pass: wr_lease=20000 pushes memts past TS_MAX within
    four storms (state.tsu_commit_write_batch's reinit branch) and the
    2-entry TSU forces victim eviction on allocation — all bit-identical
    across host / batched / scan."""
    host, batched, scan = build_triple(WRITEHOT)
    for rnd in range(4):
        items = [(k, f"{k}@{rnd}") for k in KEYS]
        for b in (host, batched, scan):
            b.write_batch(items, replica=rnd % 4)
            b.fence()
    assert_write_equivalent(host, batched, scan)
    assert host.stats()["overflow_reinits"] > 0, \
        "the batched write pass never hit the reinit branch"
    assert host.stats()["tsu_evictions"] > 0, "eviction never triggered"
    assert_state_equal(batched, scan)

    # pin that this geometry actually runs the vectorized pass (no
    # conflict-round fallback) — a distinct-key storm fits the budget
    probe = ArrayFabric(FabricConfig(**WRITEHOT), n_nodes=2,
                        replicas_per_node=2, pipeline="batched")
    assert probe._write_batch_batched([(k, "x") for k in KEYS], 0, None)

    # the synchronous-drain geometry (max_in_flight=0, one shard) takes
    # the fallback for the same storms — same bits either way
    host2, batched2, scan2 = build_triple(OVERFLOW, n_nodes=1,
                                          replicas_per_node=2)
    _drive_write_storms((host2, batched2, scan2), seed=5, n_calls=6)
    assert_write_equivalent(host2, batched2, scan2)
    assert host2.stats()["tsu_evictions"] > 0
    assert_state_equal(batched2, scan2)


def test_write_batches_counter_parity():
    """Satellite pin: every non-empty write_batch bumps the stats-block
    boundary counter on BOTH backends (empty batches don't), so the
    existing stats-equality assertions cover the write path's batch
    boundary — mirroring fast_read_batches."""
    host, arr = build_pair(SMALL)
    for b in (host, arr):
        b.write_batch([])                             # no-op, not counted
        b.write_batch([(k, f"{k}@0") for k in KEYS[:3]], replica=1)
        b.write_batch([("k0", "again")], replica=0)
    assert host.stats()["write_batches"] == arr.stats()["write_batches"] == 2
    assert host.stats() == arr.stats()
    assert arr.stats()["write_batches"] == arr._write_batches


def test_write_pass_one_collective_per_512_storm():
    """The acceptance pin: a 512-op publish storm through the sharded
    batched engine issues exactly ONE packed collective — in the
    per-batch grant-exchange program, NONE inside the write or fence
    pass (the dev0 pass engine's programs are collective-free) — while
    the per-op scan schedule keeps a collective in its scan body
    (>= 512 per storm).  Counted structurally in the jaxpr, so the pin
    holds on any mesh size."""
    import jax
    import jax.numpy as jnp

    from repro.coherence.fabric.pipeline import collective_counts

    cfg = FabricConfig(**SMALL)
    B, R = 512, 8
    counts = {}
    fab = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2,
                             pipeline="batched")
    af = fab._af
    jg = jax.make_jaxpr(fab._gather_run)(
        af.tsu, af.tsu_ver, af.tsu_gseq, af.tsu_seq, af.tsu_nseq)
    counts["gather"] = collective_counts(jg)
    ops = jnp.zeros((4, B), jnp.int32)
    sched = jnp.zeros((7, B), jnp.int32)
    masks = jnp.zeros((R, B), bool)
    s0 = jnp.int32(0)
    jw = jax.make_jaxpr(fab._write_run)(
        af, ops, sched, masks, s0, s0, jnp.int32(-1),
        jnp.int32(cfg.rd_lease), jnp.int32(cfg.wr_lease))
    counts["write_pass"] = collective_counts(jw)
    jf = jax.make_jaxpr(fab._fence_run)(
        af, jnp.zeros((8, B), jnp.int32), masks,
        jnp.int32(cfg.rd_lease), jnp.int32(cfg.wr_lease))
    counts["fence_pass"] = collective_counts(jf)
    scan = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2,
                              pipeline="scan")
    xs = {k: jnp.zeros((B,), jnp.int32) for k in
          ("kind", "rep", "node", "key", "set1", "set2", "shard", "wl")}
    js = jax.make_jaxpr(scan._run)(scan._af, xs, jnp.int32(8), jnp.int32(4))
    counts["scan"] = collective_counts(js)
    assert counts["gather"] == {"total": 1, "in_loop": 0}, counts
    assert counts["write_pass"] == {"total": 0, "in_loop": 0}, counts
    assert counts["fence_pass"] == {"total": 0, "in_loop": 0}, counts
    assert counts["scan"]["in_loop"] >= 1, counts   # >= B per 512-op storm


def test_runtime_write_batch_wiring():
    """The runtime consumers post their storms through write_batch (one
    batch boundary each): BatchedKVLease.put_batch forwards the whole
    item list, and the boundary count lands in fabric stats."""
    from repro.coherence.kv_lease import BatchedKVLease

    arr = ArrayFabric(FabricConfig(**SMALL), n_nodes=2, replicas_per_node=2)
    kv = BatchedKVLease(arr, replica=1)
    kv.put_batch([(k, f"{k}@kv") for k in KEYS[:4]])
    assert arr.stats()["write_batches"] == 1
    assert arr.stats()["writes"] == 4
    kv.fence()                  # drain the posted tail before reading back
    got = kv.get_batch(KEYS[:4])
    assert all(g is not None for g in got)


# ------------------------------------------------------- sharded fabric
def _sharded_write_multidevice_check():
    """Body of the forced-8-device write-storm parity check (run
    in-process when the session already has >= 8 devices, else via the
    subprocess harness): host oracle vs mesh-placed sharded fabric vs
    single-device array on identical write/fence storms — one TSU shard
    per device, posted write-throughs travelling over real collectives."""
    import jax

    assert len(jax.devices()) >= 8, "needs the forced 8-device host mesh"
    cfg = FabricConfig(**dict(SMALL, n_shards=8))
    host = HostFabric(cfg, n_nodes=2, replicas_per_node=2)
    sh = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    arr = ArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    assert sh.n_shard_devices == 8                 # one shard per device
    warm = random_trace(np.random.default_rng(19), 100, 4)
    for b in (host, sh, arr):
        b.apply(warm)
    oh, osh, oar = _drive_write_storms((host, sh, arr), seed=17, n_calls=10)
    assert oh == osh, "sharded write pass diverged from the host oracle"
    assert oh == oar, "sharded diverged from the single-device array"
    assert_write_equivalent(host, sh, arr)
    assert host.stats()["write_batches"] >= 10
    assert sh.stats()["bytes_inter_gpu"] > 0       # the mesh saw real hops
    assert_state_equal(sh, arr)
    return True


def test_sharded_write_parity_forced_8_devices():
    """Run ``_sharded_write_multidevice_check`` on an 8-device host mesh:
    in process if this session was launched with the forced flag (CI),
    else in a subprocess with
    XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    import jax

    if len(jax.devices()) >= 8:
        assert _sharded_write_multidevice_check()
        return
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), os.path.join(repo, "tests")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-c",
         "from test_write_parity import _sharded_write_multidevice_check; "
         "assert _sharded_write_multidevice_check(); "
         "print('SHARDED-WRITE-PARITY-OK')"],
        env=env, cwd=repo, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"forced-8-device write parity subprocess failed:\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    assert "SHARDED-WRITE-PARITY-OK" in proc.stdout


# ---------------------------------------------------------------- fuzzing
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # CI installs it via the [test] extra
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    # skewed key pool: KEYS[0] is 3x hotter, so storms collide on sets,
    # duplicate inside batches, and re-publish the same line repeatedly
    _SKEWED = st.sampled_from([KEYS[0], KEYS[0], KEYS[0]] + KEYS)
    _storm = st.one_of(
        st.tuples(st.just("batch"), st.integers(0, 3),
                  st.lists(_SKEWED, min_size=1, max_size=8),
                  st.sampled_from([None, 1, 30000])),
        st.tuples(st.just("fence"), st.just(0), st.just([]), st.just(None)),
        st.tuples(st.just("read"), st.integers(0, 3),
                  st.lists(_SKEWED, min_size=1, max_size=1), st.just(None)),
        st.tuples(st.just("mm_write"), st.just(0),
                  st.lists(_SKEWED, min_size=1, max_size=1),
                  st.sampled_from([None, 30000])),
    )

    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(_storm, min_size=1, max_size=8))
    def test_hypothesis_write_fence_storms(storms):
        """Fuzz the write/fence contract: random sequences of publish
        storms (skewed + duplicate keys, random write leases incl. the
        overflow-forcing 30000), fences over non-empty queues, authority
        writes and reads — host vs batched vs scan, everything equal."""
        host, batched, scan = build_triple(SMALL)
        for t, (kind, rep, ks, wl) in enumerate(storms):
            if kind == "read":
                rh = host.read(ks[0], replica=rep)
                assert rh == batched.read(ks[0], replica=rep)
                assert rh == scan.read(ks[0], replica=rep)
                continue
            for b in (host, batched, scan):
                if kind == "batch":
                    b.write_batch([(k, f"v{t}.{i}")
                                   for i, k in enumerate(ks)],
                                  replica=rep, wr_lease=wl)
                elif kind == "fence":
                    b.fence()
                else:
                    b.mm_write(ks[0], f"m{t}", wr_lease=wl)
        assert_write_equivalent(host, batched, scan)
        assert_state_equal(batched, scan)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_write_fence_storms():
        pass
