"""Core layer primitives: RMSNorm, RoPE, memory-efficient attention, chunked CE.

Everything is pure jnp (the XLA path used for dry-run lowering); Pallas kernels in
``repro.kernels`` provide drop-in TPU implementations validated against these.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

_NEG_INF = -1e30


def rmsnorm(x, w, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(dt)


def rope_freqs(d_head: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x, positions, theta: float = 1e4):
    """x: [..., S, H, D]; positions: [..., S] or [S]."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # [D/2]
    ang = positions[..., None].astype(jnp.float32) * freqs   # [..., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]                            # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def _mask_bias(q_pos, k_pos, causal: bool, window: int, kv_len: Optional[int]):
    """[Sq, Sk] additive bias in f32."""
    m = jnp.zeros((q_pos.shape[0], k_pos.shape[0]), jnp.float32)
    if causal:
        m = jnp.where(k_pos[None, :] > q_pos[:, None], _NEG_INF, m)
    if window:
        m = jnp.where(q_pos[:, None] - k_pos[None, :] >= window, _NEG_INF, m)
    if kv_len is not None:   # decode: cache positions beyond filled length
        m = jnp.where(k_pos[None, :] >= kv_len, _NEG_INF, m)
    return m


def attention(q, k, v, *, causal=True, window=0, q_offset=0, kv_len=None,
              chunk=1024, softmax_scale=None):
    """Memory-efficient GQA attention.

    q: [B, Sq, Hq, D]; k, v: [B, Sk, Hkv, D].  Scans over q chunks so the live
    score buffer is [B, Hkv, qpk, chunk, Sk] instead of [.., Sq, Sk].
    q_offset: absolute position of q[0] (prefill=0; decode=pos).
    kv_len: number of valid cache entries (decode), None for train/prefill.
    """
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    Dv = v.shape[-1]
    qpk = Hq // Hkv
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qr = q.reshape(B, Sq, Hkv, qpk, D)
    k_pos = jnp.arange(Sk)

    def block(q_blk, qpos_blk):
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q_blk, k,
                       preferred_element_type=jnp.float32) * scale
        s = s + _mask_bias(qpos_blk, k_pos, causal, window, kv_len)
        p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
        return jnp.einsum("bhgqk,bkhd->bqhgd", p, v,
                          preferred_element_type=jnp.float32)

    if Sq <= chunk:
        out = block(qr, q_offset + jnp.arange(Sq))
    else:
        n = Sq // chunk
        assert Sq % chunk == 0, (Sq, chunk)
        qs = qr.reshape(B, n, chunk, Hkv, qpk, D).transpose(1, 0, 2, 3, 4, 5)
        pos = (q_offset + jnp.arange(Sq)).reshape(n, chunk)

        def body(_, xs):
            qb, pb = xs
            return None, block(qb, pb)

        _, out = jax.lax.scan(body, None, (qs, pos))
        out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, Hkv, qpk, Dv)
    return out.reshape(B, Sq, Hq, Dv).astype(q.dtype)


def swiglu(x, wg, wi, wo, compute_dtype):
    g = x @ wg.astype(compute_dtype)
    u = x @ wi.astype(compute_dtype)
    return (jax.nn.silu(g.astype(jnp.float32)).astype(compute_dtype) * u) @ wo.astype(compute_dtype)


def chunked_xent(h, unembed, labels, mask=None, chunk=512):
    """Next-token CE without materializing [B, S, V] logits.

    h: [B, S, D] (already shifted so h[t] predicts labels[t]);
    unembed: [D, V]; labels: [B, S] int32; mask: [B, S] or None.
    """
    B, S, D = h.shape
    V = unembed.shape[-1]
    chunk = min(chunk, S)
    n = S // chunk
    assert S % chunk == 0, (S, chunk)
    hs = h.reshape(B, n, chunk, D).transpose(1, 0, 2, 3)
    ls = labels.reshape(B, n, chunk).transpose(1, 0, 2)
    ms = (mask.reshape(B, n, chunk).transpose(1, 0, 2)
          if mask is not None else jnp.ones_like(ls, jnp.float32))

    def body(carry, xs):
        hb, lb, mb = xs
        logits = (hb @ unembed.astype(hb.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        tgt = jnp.take_along_axis(logits, lb[..., None], axis=-1)[..., 0]
        loss = (lse - tgt) * mb
        return (carry[0] + loss.sum(), carry[1] + mb.sum()), None

    (tot, cnt), _ = jax.lax.scan(body, (jnp.zeros((), jnp.float32),
                                        jnp.zeros((), jnp.float32)), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def update_cache(cache_kv, new_kv, pos):
    """cache_kv: [B, S_max, F]; new_kv: [B, s, F]; pos: scalar start index."""
    return jax.lax.dynamic_update_slice(cache_kv, new_kv.astype(cache_kv.dtype),
                                        (0, pos, 0))
