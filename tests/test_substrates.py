"""Substrate tests: data pipeline, checkpoint/restore+reshard, trainer
fault-tolerance (restart, elastic, straggler watchdog), serving runtime,
lease-coherent KV cache, lease-sync local SGD, gradient compression."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs as cfgs
from repro.checkpoint.manager import CheckpointManager
from repro.coherence.kv_lease import AuthoritativeStore, LeaseKVCache
from repro.coherence.lease_sync import LeaseConfig, VmappedWorkers
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.optim import adamw
from repro.optim.compress import dequantize, ef_compress, quantize
from repro.runtime.server import Request, Server
from repro.runtime.trainer import Trainer, TrainerConfig


SMOKE = cfgs.SMOKE["smollm-360m"]


def tiny_data(cfg, B=2, S=32):
    return SyntheticLM(cfg, DataConfig(global_batch=B, seq_len=S))


# ------------------------------------------------------------------ data
def test_data_deterministic_and_shardable():
    d1 = tiny_data(SMOKE)
    d2 = tiny_data(SMOKE)
    b1, b2 = d1.batch(7), d2.batch(7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (2, 32)
    assert (d1.batch(8)["tokens"] != b1["tokens"]).any()
    # host slicing partitions the global batch
    g = SyntheticLM(SMOKE, DataConfig(global_batch=4, seq_len=32))
    h0 = SyntheticLM(SMOKE, DataConfig(global_batch=4, seq_len=32,
                                       host_index=0, host_count=2))
    h1 = SyntheticLM(SMOKE, DataConfig(global_batch=4, seq_len=32,
                                       host_index=1, host_count=2))
    np.testing.assert_array_equal(
        np.concatenate([h0.batch(3)["tokens"], h1.batch(3)["tokens"]]),
        g.batch(3)["tokens"])


# ------------------------------------------------------------ checkpoint
def test_checkpoint_roundtrip_and_reshard(tmp_path):
    from repro.models import init_model
    params = init_model(SMOKE, jax.random.PRNGKey(0))
    state = adamw.init_state(params)
    mgr = CheckpointManager(tmp_path, keep=2, async_write=True)
    mgr.save(10, state)
    mgr.save(20, state)
    mgr.save(30, state)
    mgr.wait()
    assert mgr.latest_step() == 30
    # keep=2 garbage-collects the oldest
    assert not (tmp_path / "step_00000010").exists()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.models import model_shardings
    psh = model_shardings(SMOKE, mesh)
    ssh = adamw.state_shardings(psh, mesh)
    got = mgr.restore(None, state, ssh)
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(got.params)[0]),
        np.asarray(jax.tree.leaves(state.params)[0]))


# ---------------------------------------------------------- trainer FT
@pytest.fixture(scope="module")
def micro_trainer_cfg():
    return cfgs.SMOKE["mamba2-130m"]


def test_trainer_checkpoint_restart(tmp_path, micro_trainer_cfg):
    cfg = micro_trainer_cfg
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    data = tiny_data(cfg)
    t = Trainer(cfg, mesh, tcfg=TrainerConfig(total_steps=8, ckpt_period=4,
                                              ckpt_dir=str(tmp_path)),
                data=data)
    with pytest.raises(RuntimeError, match="simulated node failure"):
        t.run(fail_at=6)
    # restart from step 4 checkpoint and finish
    res = t.resume()
    assert res["final_step"] == 8
    assert any(e["kind"] == "restore" and e["step"] == 4 for e in t.events)
    assert all(np.isfinite(res["losses"]))


def test_trainer_elastic_remesh(tmp_path, micro_trainer_cfg):
    cfg = micro_trainer_cfg
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    data = tiny_data(cfg)
    t = Trainer(cfg, mesh, tcfg=TrainerConfig(total_steps=6, ckpt_period=3,
                                              ckpt_dir=str(tmp_path)),
                data=data)
    with pytest.raises(RuntimeError):
        t.run(fail_at=4)
    new_mesh = jax.make_mesh((1, 1), ("data", "model"))  # "smaller" cluster
    res = t.resume(mesh=new_mesh)
    assert res["final_step"] == 6
    assert any(e["kind"] == "elastic_remesh" for e in t.events)


# ------------------------------------------------------------- serving
def test_server_prefix_cache_coherence():
    cfg = SMOKE
    from repro.models import init_model
    params = init_model(cfg, jax.random.PRNGKey(1))
    srv = Server(cfg, params, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab, 16).astype(np.int32)
    reqs = [Request(rid=i, prompt=prompt, max_new=4) for i in range(4)]
    out = srv.serve(reqs)
    assert set(out) == {0, 1, 2, 3}
    # the call's identical groups share ONE batched probe + one prefill;
    # a repeated serve is a lease hit (no second prefill write-through)
    out2 = srv.serve(reqs)
    assert srv.cache_stats["hits"] >= 1
    np.testing.assert_array_equal(out[0], out[2])
    np.testing.assert_array_equal(out[0], out2[0])


def test_lease_kv_cache_protocol_semantics():
    store = AuthoritativeStore(rd_lease=8, wr_lease=4)
    r1 = LeaseKVCache(store)
    r2 = LeaseKVCache(store)
    r1.put("p", "v1")
    assert r2.get("p")[0] == "v1"              # compulsory fetch
    assert r2.get("p")[0] == "v1"              # lease hit
    assert r2.stats["hits"] == 1
    r1.put("p", "v2")                          # writer updates; NO inval msg
    got = r2.get("p")[0]
    assert got in ("v1", "v2")                 # weakly consistent window
    r2.cts = store.blocks["p"].memts + 1       # reader syncs (fence)
    assert r2.get("p")[0] == "v2"              # lease expired -> coherent
    assert r2.stats["coherence_misses"] >= 1


# ----------------------------------------------------- lease local-SGD
def test_lease_sync_w1_equals_sync_dp():
    cfg = cfgs.SMOKE["smollm-360m"]
    data = tiny_data(cfg)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=10)
    w = VmappedWorkers(cfg, opt, LeaseConfig(wr_lease=1), n_workers=2,
                       key=jax.random.PRNGKey(0))
    mk = lambda s: {"tokens": np.stack([data.batch(s)["tokens"][0],
                                        data.batch(s)["tokens"][1]])[:, None][:, 0][None].repeat(2, 0)[..., :32]}
    # simpler: two workers, two different single-row batches
    for s in range(2):
        b = data.batch(s)["tokens"]
        batches = {"tokens": np.stack([b[0:1], b[1:2]])}
        w.step(batches)
    p = jax.tree.leaves(w.state.params)[0]
    np.testing.assert_allclose(np.asarray(p[0]), np.asarray(p[1]),
                               rtol=1e-5, atol=1e-6)


def test_lease_sync_reduces_collective_bytes():
    cfg = cfgs.SMOKE["smollm-360m"]
    data = tiny_data(cfg)
    opt = adamw.AdamWConfig(lr=1e-3, warmup_steps=1, total_steps=20)
    key = jax.random.PRNGKey(0)
    w1 = VmappedWorkers(cfg, opt, LeaseConfig(wr_lease=1), 2, key)
    w4 = VmappedWorkers(cfg, opt, LeaseConfig(wr_lease=4), 2, key)
    for s in range(8):
        b = data.batch(s)["tokens"]
        batches = {"tokens": np.stack([b[0:1], b[1:2]])}
        l1 = w1.step(batches)
        l4 = w4.step(batches)
    assert w4.collective_bytes * 3 < w1.collective_bytes
    assert np.isfinite(l1) and np.isfinite(l4)
    # after the final sync both replicas agree (write-through invariant)
    p = jax.tree.leaves(w4.state.params)[0]
    np.testing.assert_allclose(np.asarray(p[0]), np.asarray(p[1]),
                               rtol=1e-5, atol=1e-6)
    assert w4.clock.memts > 0                      # Lamport clock advanced


# ---------------------------------------------------------- compression
def test_quantize_roundtrip_bounded_error():
    rng = np.random.default_rng(0)
    x = rng.standard_normal((128, 64)).astype(np.float32)
    q, s = quantize(jnp.asarray(x))
    err = np.abs(dequantize(q, s) - x)
    assert err.max() <= float(np.abs(x).max()) / 127.0 + 1e-6


def test_error_feedback_accumulates_to_unbiased():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.standard_normal(1024).astype(np.float32)) * 1e-3
    err = jnp.zeros_like(g)
    total_sent = jnp.zeros_like(g)
    for _ in range(50):
        sent, err = ef_compress(g, err)
        total_sent = total_sent + sent
    # long-run average of transmitted gradient matches the true gradient
    np.testing.assert_allclose(np.asarray(total_sent / 50), np.asarray(g),
                               atol=float(jnp.abs(g).max()) / 40)
