"""Open-loop load generation: bounded-Zipf key popularity + arrival
processes + recordable request traces (ISSUE 9 tentpole, part 1).

Every closed-loop driver in ``benchmarks/`` forms its next batch only
after the previous one returns — the workload shape the paper benchmarks,
but not what a serving stack sees.  This module synthesizes (or replays)
*arrival-timestamped* request streams: each request is a (t_arrive,
key_id) pair, keys drawn from a properly **bounded** Zipf and timestamps
from Poisson / diurnal / bursty processes.  ``runtime/scheduler.py``
replays a trace open-loop against a ``FabricBackend``;
``benchmarks/replay_bench.py`` sweeps offered load and reports
p50/p95/p99 + SLO goodput (BENCH_serving.json).

Bounded Zipf (the ISSUE 9 Zipf-bug satellite): ``numpy``'s ``rng.zipf(a)``
samples the UNBOUNDED Zipf distribution; the previously idiomatic
``rng.zipf(a) % n`` wraps the infinite tail back onto ``[0, n)``, which
silently FLATTENS the skew — rank 0 receives every tail sample that is
``0 mod n``, rank 1 every ``1 mod n``, and so on, so the wrapped pmf is
the true head pmf plus an almost-uniform wrap term.  ``BoundedZipf``
instead samples the *truncated* distribution exactly: pmf(k) ∝ 1/(k+1)^a
on ranks ``0..n-1`` via inverse-CDF over the precomputed normalized
weights.  Everything in this repo that draws skewed keys goes through it
(``benchmarks/fabric_bench.py``, ``data/pipeline.py``).

This module is numpy-only (no jax) so traces can be generated/loaded in
drivers, tests, and CI without touching the device runtime.
"""
from __future__ import annotations

import dataclasses
import functools
import pathlib
from typing import Dict, Optional, Union

import numpy as np


# ----------------------------------------------------------- key popularity
class BoundedZipf:
    """Exact truncated Zipf over ranks ``0..n-1``: pmf(k) ∝ 1/(k+1)^a.

    Inverse-CDF sampling over the precomputed normalized weight table —
    no unbounded tail, no modulo wrap, O(log n) per draw.
    """

    def __init__(self, n: int, a: float = 1.5):
        if n < 1:
            raise ValueError(f"need n >= 1 ranks, got {n}")
        if a <= 0:
            raise ValueError(f"need skew a > 0, got {a}")
        self.n, self.a = int(n), float(a)
        w = np.arange(1, self.n + 1, dtype=np.float64) ** -self.a
        self._pmf = w / w.sum()
        self._cdf = np.cumsum(self._pmf)
        self._cdf[-1] = 1.0                    # guard fp round-down

    def pmf(self) -> np.ndarray:
        """Exact probability of each rank, [n] float64 (sums to 1)."""
        return self._pmf.copy()

    def sample(self, rng: np.random.Generator,
               size: Optional[int] = None) -> Union[int, np.ndarray]:
        """Draw ranks in ``[0, n)``; scalar int when ``size`` is None."""
        u = rng.random(size)
        out = np.searchsorted(self._cdf, u, side="right").astype(np.int64)
        return int(out) if size is None else out


@functools.lru_cache(maxsize=64)
def bounded_zipf(n: int, a: float = 1.5) -> BoundedZipf:
    """Memoized ``BoundedZipf`` — callers that draw per-item (e.g. the
    synthetic-corpus doc generator) amortize the CDF build."""
    return BoundedZipf(n, a)


# --------------------------------------------------------- arrival processes
def poisson_arrivals(rng: np.random.Generator, n: int,
                     rate: float) -> np.ndarray:
    """Homogeneous Poisson: iid exponential gaps at ``rate`` req/s."""
    if rate <= 0:
        raise ValueError(f"need rate > 0, got {rate}")
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def diurnal_arrivals(rng: np.random.Generator, n: int, rate: float,
                     period_s: Optional[float] = None,
                     amplitude: float = 0.85,
                     cycles: float = 3.0) -> np.ndarray:
    """Inhomogeneous Poisson with a sinusoidal (day/night) rate:
    ``rate(t) = rate * (1 + amplitude*sin(2π t/period))`` — peaks at
    ``(1+A)x`` the mean, troughs at ``(1-A)x``.  Generated sequentially
    (each gap drawn at the current instantaneous rate), which is the
    standard piecewise approximation and exact in the period >> gap
    regime the bench runs in.  Default period spans ``cycles`` full
    day/night swings over the n requests."""
    if not 0.0 <= amplitude < 1.0:
        raise ValueError(f"need 0 <= amplitude < 1, got {amplitude}")
    if period_s is None:
        period_s = n / (rate * cycles)
    t, out = 0.0, np.empty(n, np.float64)
    gaps = rng.exponential(1.0, size=n)        # unit-rate, rescaled per gap
    w = 2.0 * np.pi / period_s
    for i in range(n):
        lam = rate * (1.0 + amplitude * np.sin(w * t))
        t += gaps[i] / max(lam, 1e-12)
        out[i] = t
    return out


def burst_arrivals(rng: np.random.Generator, n: int, rate: float,
                   burst: float = 8.0, p_burst: float = 0.02,
                   mean_burst_len: int = 32) -> np.ndarray:
    """Markov-modulated Poisson (flash crowds): a two-state chain flips
    between the base ``rate`` and ``burst * rate``; bursts start with
    probability ``p_burst`` per arrival and last ``mean_burst_len``
    arrivals in expectation (geometric)."""
    if burst < 1.0:
        raise ValueError(f"need burst >= 1, got {burst}")
    p_exit = 1.0 / max(mean_burst_len, 1)
    gaps = rng.exponential(1.0, size=n)
    flips = rng.random(n)
    t, hot, out = 0.0, False, np.empty(n, np.float64)
    for i in range(n):
        hot = (flips[i] >= p_exit) if hot else (flips[i] < p_burst)
        t += gaps[i] / (rate * burst if hot else rate)
        out[i] = t
    return out


PROCESSES = {"poisson": poisson_arrivals, "diurnal": diurnal_arrivals,
             "burst": burst_arrivals}


# ------------------------------------------------------------ request traces
@dataclasses.dataclass(frozen=True)
class RequestTrace:
    """An arrival-timestamped key stream: request ``i`` asks for key
    ``kid[i]`` at ``t[i]`` seconds (nondecreasing float64).  ``n_keys``
    bounds the key-id space (kids are ranks of the popularity law)."""

    t: np.ndarray                 # [n] float64, nondecreasing
    kid: np.ndarray               # [n] int32 in [0, n_keys)
    n_keys: int
    meta: Dict[str, object] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if len(self.t) != len(self.kid):
            raise ValueError("t and kid length mismatch")
        if len(self.t) and np.any(np.diff(self.t) < 0):
            raise ValueError("arrival timestamps must be nondecreasing")
        if len(self.kid) and (self.kid.min() < 0
                              or self.kid.max() >= self.n_keys):
            raise ValueError("key ids out of [0, n_keys)")

    def __len__(self) -> int:
        return len(self.t)

    @property
    def offered_rps(self) -> float:
        """Mean offered load of the trace as recorded."""
        return len(self.t) / max(float(self.t[-1]), 1e-12)

    def scaled(self, factor: float) -> "RequestTrace":
        """Rescale the TIME axis only (t/factor → factor x the offered
        rate).  The key sequence is untouched, so every offered-load
        point in a sweep replays the IDENTICAL key stream — the property
        the Fig-10 decomposition's 'same key stream' comparison needs."""
        if factor <= 0:
            raise ValueError(f"need factor > 0, got {factor}")
        return dataclasses.replace(
            self, t=self.t / factor,
            meta={**self.meta, "scaled_by": factor})

    # ----------------------------------------------------- record / replay
    def save(self, path) -> None:
        """Record the trace (npz) for later replay."""
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        np.savez_compressed(path, t=self.t, kid=self.kid,
                            n_keys=np.int64(self.n_keys),
                            meta=np.frombuffer(
                                repr(self.meta).encode(), dtype=np.uint8))

    @staticmethod
    def load(path) -> "RequestTrace":
        with np.load(pathlib.Path(path)) as z:
            meta = {}
            if "meta" in z:
                import ast
                try:
                    meta = ast.literal_eval(bytes(z["meta"]).decode())
                except (ValueError, SyntaxError):
                    meta = {}
            return RequestTrace(t=z["t"].astype(np.float64),
                                kid=z["kid"].astype(np.int32),
                                n_keys=int(z["n_keys"]), meta=meta)


def synthesize(n_requests: int, n_keys: int, *, a: float = 1.2,
               process: str = "poisson", rate: float = 1.0,
               seed: int = 0, **proc_kw) -> RequestTrace:
    """One call = one million-user-shaped stream: ``n_requests`` keys from
    ``BoundedZipf(n_keys, a)`` with arrival timestamps from the named
    process at mean ``rate`` req/s.  Deterministic in ``seed``."""
    if process not in PROCESSES:
        raise ValueError(f"unknown process {process!r}; "
                         f"one of {sorted(PROCESSES)}")
    rng = np.random.default_rng(seed)
    t = PROCESSES[process](rng, n_requests, rate, **proc_kw)
    kid = BoundedZipf(n_keys, a).sample(rng, size=n_requests)
    return RequestTrace(
        t=np.asarray(t, np.float64), kid=kid.astype(np.int32),
        n_keys=n_keys,
        meta={"process": process, "rate": rate, "a": a, "seed": seed,
              **proc_kw})
