"""HALCONE lease-probe kernel: the protocol engine's hot inner loop
(tag compare + lease check + Algorithm 1/2 install math), batched over all
concurrent requests.  This is the paper's per-request coherence action as a
single fused VMEM pass — the Pallas face of repro.core.protocol, and since
the batched sweep engine (DESIGN.md §5) the op that serves every L1 and L2
probe+install inside ``core.engine``'s round step.

Backend selection is a runtime decision: with ``interpret=None`` (the
default, used by the engine) the kernel compiles natively on TPU/GPU and
falls back to interpret mode on CPU, where Pallas has no native lowering.
Interpret mode traces the identical kernel body into plain XLA ops, so the
engine's math is bit-identical across backends.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(tag_ref, rts_ref, cts_ref, addr_ref, mwts_ref, mrts_ref,
                  taghit_ref, hit_ref, way_ref, rowrts_ref, nwts_ref,
                  nrts_ref, ncts_ref):
    tags = tag_ref[...]                                 # [bn, W]
    rts = rts_ref[...]
    cts = cts_ref[...]
    addr = addr_ref[...]
    eq = tags == addr[:, None]
    tag_hit = eq.any(axis=-1)
    way = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    # first-match way only: the engine can hold a stale duplicate of a tag
    # (coherence-miss installs go to a victim way while the expired copy
    # stays live), and the probe must read the same way argmax selects
    first = eq & (jnp.cumsum(eq.astype(jnp.int32), axis=-1) == 1)
    row_rts = jnp.sum(jnp.where(first, rts, 0), axis=-1)
    hit = tag_hit & (cts <= row_rts)                    # protocol.valid
    # protocol.install: Bwts = max(cts, Mwts); Brts = max(Bwts+1, Mrts)
    bwts = jnp.maximum(cts, mwts_ref[...])
    brts = jnp.maximum(bwts + 1, mrts_ref[...])
    taghit_ref[...] = tag_hit.astype(jnp.int32)
    hit_ref[...] = hit.astype(jnp.int32)
    way_ref[...] = way
    rowrts_ref[...] = row_rts
    nwts_ref[...] = bwts
    nrts_ref[...] = brts
    ncts_ref[...] = jnp.maximum(cts, bwts)              # cts_after_write


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def lease_probe(tag_rows, rts_rows, cts, addr, mwts, mrts, *, bn=256,
                interpret=None):
    """Fused probe + install over gathered set rows.

    tag_rows/rts_rows: [N, W] live ways of each request's set; cts/addr/
    mwts/mrts: [N] (int32).  (mwts, mrts) is the response lease arriving
    from the level below (TSU grant for an L2 probe, L2 response for an L1
    probe).

    Returns (tag_hit, hit, way, row_rts, new_wts, new_rts, new_cts):
      tag_hit  — tag match on a live way (coherency misses = tag_hit & ~hit)
      hit      — tag match AND lease valid (cts <= rts;  protocol.valid)
      way      — the matching way (meaningful only under tag_hit)
      row_rts  — rts of the matching way (0 when no tag match)
      new_wts/new_rts — protocol.install(cts, mwts, mrts)
      new_cts  — protocol.cts_after_write(cts, new_wts)

    ``interpret=None`` selects the backend at runtime: compiled Pallas on
    TPU/GPU, interpret fallback on CPU."""
    if interpret is None:
        interpret = jax.default_backend() not in ("tpu", "gpu", "cuda",
                                                  "rocm")
    N, W = tag_rows.shape
    bn = min(bn, N)
    while N % bn:
        bn -= 1
    grid = (N // bn,)
    row = lambda i: (i, 0)
    vec = lambda i: (i,)
    outs = pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bn, W), row), pl.BlockSpec((bn, W), row),
                  pl.BlockSpec((bn,), vec), pl.BlockSpec((bn,), vec),
                  pl.BlockSpec((bn,), vec), pl.BlockSpec((bn,), vec)],
        out_specs=[pl.BlockSpec((bn,), vec)] * 7,
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.int32)] * 7,
        interpret=interpret,
    )(tag_rows, rts_rows, cts, addr, mwts, mrts)
    tag_hit, hit, way, row_rts, nwts, nrts, ncts = outs
    return (tag_hit.astype(bool), hit.astype(bool), way, row_rts, nwts,
            nrts, ncts)
