"""Roofline table from the dry-run artifacts (launch/dryrun.py must have run;
this reads benchmarks/artifacts/dryrun/<mesh>[/variant]/*.json)."""
import json
import pathlib

from benchmarks.common import emit

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"


def load(mesh="single"):
    recs = []
    d = ART / mesh
    if not d.exists():
        return recs
    for p in sorted(d.glob("*.json")):
        recs.append(json.loads(p.read_text()))
    return recs


def main(force=False):
    for mesh in ("single", "multi"):
        for r in load(mesh):
            rl = r["roofline"]
            dom = max(rl["t_compute_s"], rl["t_memory_s"],
                      rl["t_collective_s"])
            emit(f"roofline/{mesh}/{r['arch']}/{r['shape']}",
                 r["compile_s"] * 1e6,
                 f"tc={rl['t_compute_s']:.3e};tm={rl['t_memory_s']:.3e};"
                 f"tx={rl['t_collective_s']:.3e};bn={rl['bottleneck']};"
                 f"useful={rl['useful_flop_ratio']:.3f}")
    # optimized variants (written by the §Perf hillclimb)
    for d in sorted(ART.glob("single-*")):
        for p in sorted(d.glob("*.json")):
            r = json.loads(p.read_text())
            rl = r["roofline"]
            emit(f"roofline/{d.name}/{r['arch']}/{r['shape']}",
                 r["compile_s"] * 1e6,
                 f"tc={rl['t_compute_s']:.3e};tm={rl['t_memory_s']:.3e};"
                 f"tx={rl['t_collective_s']:.3e};bn={rl['bottleneck']}")


if __name__ == "__main__":
    main()
