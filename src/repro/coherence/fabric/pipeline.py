"""The batched grant pipeline: `read_batch` phase 2 as vectorized passes.

PR 3's two-phase batched read served every replica-tier lease hit with ONE
vectorized probe (phase 1) but re-ran the miss subset through the exact
per-op scan — so a miss-heavy serving batch still paid one scan step (and,
sharded, one grant collective) per op.  This module completes the fast
path (ISSUE 5 tentpole, DESIGN.md §9): the whole miss subset is served by
a SECOND vectorized pass — one batched tier probe, one batched TSU grant
(``state.tsu_lease_batch``), one batched fill per tier — so a batch costs
O(tiers) array ops and, on the sharded fabric, ONE packed grant collective
instead of O(ops).

Bit-identity with the sequential oracle (`HostFabric`, and the
``pipeline="scan"`` op-scan) is preserved by executing the pass over
**conflict-free rounds**:

  * ``conflict_rounds`` splits the miss subset, in op order, into maximal
    contiguous segments in which no two ops share a key, a replica-tier
    set, or a shared-tier set.  Ops in one round touch disjoint cache
    state (distinct TSU entries — keys are distinct; distinct tier sets —
    so probes, victim choices and fills cannot observe each other), hence
    executing them simultaneously equals executing them sequentially.
  * The one piece of state every op shares — the per-store LRU tick — is
    reproduced exactly with prefix-sum rank math: op *i*'s touch writes
    ``tick0 + cumsum(touch+fill)[i] - fill[i]`` and its fill writes
    ``tick0 + cumsum(touch+fill)[i]``, the precise values the sequential
    scan would have written (see DESIGN.md §9 for the proof).

All rounds run inside ONE jitted ``lax.scan`` over the round masks (the
fabric state is the scan carry, so XLA updates it in place; per-op
results accumulate into one packed ``[7, M]`` buffer), and on the sharded
fabric the packed TSU buffer is assembled ONCE before the round scan —
the per-batch collective budget stays O(1) no matter how many rounds the
subset needs.

A serving batch (deduplicated keys, sets spread by ``stable_hash``) is a
single round; pathological batches degrade to a few rounds, and
``ArrayFabric.read_batch`` falls back to the op-scan beyond a small round
budget — ordering-sensitive debugging can force that path permanently
with ``pipeline="scan"``.

``make_miss_pass`` returns the pure pass; `arrays.py` owns jitting and the
mesh placement (packed-TSU ``owner_gather`` in, ``owner_take`` out).
``collective_counts`` walks a jaxpr and reports how many collectives it
contains and how many sit inside a scan/while loop — the parity suite's
O(1)-collectives-per-batch pin and the ``batched_grants`` benchmark row
both read it.
"""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.coherence.fabric.stats import GI, G_KEYS, RI, R_KEYS
from repro.core import state as S
# the packed per-op result block ([7, M] int32) — the layout contract now
# lives in core.state so the simulator's round step emits the same record
# (re-exported here for existing consumers)
from repro.core.state import RES_FIELDS  # noqa: F401


def conflict_rounds(kids, s1, s2) -> List[np.ndarray]:
    """Split a miss subset (op order) into maximal contiguous conflict-free
    rounds: within a round all keys, replica sets and shared sets are
    distinct.  Returns index arrays into the subset; concatenated they are
    ``range(len(kids))`` — rounds never reorder ops, so committing them in
    round order IS the sequential op order."""
    rounds: List[np.ndarray] = []
    cur: List[int] = []
    seen_k, seen_1, seen_2 = set(), set(), set()
    for i, (k, a, b) in enumerate(zip(np.asarray(kids).tolist(),
                                      np.asarray(s1).tolist(),
                                      np.asarray(s2).tolist())):
        if k in seen_k or a in seen_1 or b in seen_2:
            rounds.append(np.asarray(cur, np.int64))
            cur = []
            seen_k, seen_1, seen_2 = set(), set(), set()
        cur.append(i)
        seen_k.add(k)
        seen_1.add(a)
        seen_2.add(b)
    rounds.append(np.asarray(cur, np.int64))
    return rounds


def round_masks(rounds: List[np.ndarray], n_rounds: int,
                width: int) -> np.ndarray:
    """Pack conflict rounds into a dense ``[n_rounds, width]`` bool mask
    matrix (rows beyond ``len(rounds)`` are empty — a fully masked pass is
    a no-op), the shape the one-jit round scan consumes."""
    masks = np.zeros((n_rounds, width), bool)
    for r, idxs in enumerate(rounds):
        masks[r, idxs] = True
    return masks


def make_miss_pass(W1: int, W2: int, KS: int):
    """Build the vectorized miss pass for one tier geometry (W1/W2 = tier
    way counts, i.e. the trash-way indices; KS = TSU shard count).

    The returned function has the signature
    ``pass_(af, kids, s1, s2, shard, masks, rep, node, rd, wr)
    -> (af, res)`` where ``af`` is the fabric state pytree (arrays._AF),
    kids/s1/s2/shard are [M] int32 op arrays (padded), ``masks`` is the
    [R, M] conflict-round matrix (each row one conflict-free round),
    rep/node are scalars (one replica per read_batch call), and ``res``
    is the packed [7, M] per-op result block (``RES_FIELDS`` order) of
    the op-scan's read path.

    The rounds run as ONE ``lax.scan`` with the fabric state as carry;
    each round body is the read path of ``arrays._build_run``'s step
    function re-expressed over a whole conflict-free round at once —
    every lease decision is the same ``core.state`` call the scan makes.
    """
    i32 = jnp.int32
    NG, NR = len(G_KEYS), len(R_KEYS)
    b2i = lambda b: b.astype(i32)

    def gsum(**kw):
        out = jnp.zeros((NG,), i32)
        return out.at[jnp.array([GI[k] for k in kw], i32)].add(
            jnp.stack(list(kw.values())))

    def rsum(**kw):
        out = jnp.zeros((NR,), i32)
        return out.at[jnp.array([RI[k] for k in kw], i32)].add(
            jnp.stack(list(kw.values())))

    def round_body(af, out, act, kids, s1, s2, shard, rep, node, rd, wr):
        M = kids.shape[0]
        z = jnp.zeros((M,), i32)
        reps = jnp.full((M,), rep, i32)
        nodes = jnp.full((M,), node, i32)

        # ---- replica probe (ReplicaCache.get): classify + self-invalidate
        th1, h1, way1, _, _, _, _ = S.tier_probe(af.rp, reps, s1, kids, z, z)
        th1, h1 = th1 & act, h1 & act
        hit_ver = af.rp.ver[reps, s1, way1]
        hit_gs = af.rp_gseq[reps, s1, way1]
        miss = act & ~h1
        coh = miss & th1
        comp = miss & ~th1
        w1d = jnp.where(coh, way1, W1)
        rp_tag = af.rp.tag.at[reps, s1, w1d].set(
            jnp.where(coh, S.INVALID, af.rp.tag[reps, s1, w1d]))

        # ---- shared probe (SharedCache.get, only on a replica miss)
        th2, h2, way2, _, _, _, _ = S.tier_probe(af.sh, nodes, s2, kids, z, z)
        th2, h2 = th2 & miss, h2 & miss
        sh_ver = af.sh.ver[nodes, s2, way2]
        sh_gs = af.sh_gseq[nodes, s2, way2]
        sh_wts = af.sh.wts[nodes, s2, way2]
        sh_rts = af.sh.rts[nodes, s2, way2]
        coh2 = miss & th2 & ~h2
        w2d = jnp.where(coh2, way2, W2)
        sh_tag = af.sh.tag.at[nodes, s2, w2d].set(
            jnp.where(coh2, S.INVALID, af.sh.tag[nodes, s2, w2d]))

        # ---- ONE batched TSU grant for the whole round (state rules)
        need_mm = miss & ~h2
        found, mwts, mrts, mver, mgs, ovf, tsu2 = S.tsu_lease_batch(
            af.tsu, af.tsu_ver, af.tsu_gseq, shard, kids, rd, wr, need_mm)
        fndF = need_mm & found
        home_miss = shard != node % KS

        # ---- response chain (what travels up to each tier)
        resp_found = h2 | fndF
        nwA, nrA, _ = S.install_lease(af.sh.cts[nodes], mwts, mrts)
        resp_ver = jnp.where(h2, sh_ver, mver)
        resp_gs = jnp.where(h2, sh_gs, mgs)
        resp_wts = jnp.where(h2, sh_wts, nwA)
        resp_rts = jnp.where(h2, sh_rts, nrA)
        nw1, nr1, _ = S.install_lease(af.rp.cts[reps], resp_wts, resp_rts)

        # ---- sequential tick math (the op-scan's exact LRU trajectory):
        # per op the touch bump precedes the install bump, so op i's touch
        # writes tick0 + c[i] - fill[i] and its install tick0 + c[i] with
        # c = cumsum(touch + fill) — prefix sums over op order.
        c1 = jnp.cumsum(b2i(th1) + b2i(resp_found))
        lru_t1 = af.rp_tick[rep] + c1 - b2i(resp_found)
        lru_f1 = af.rp_tick[rep] + c1
        c2 = jnp.cumsum(b2i(th2) + b2i(fndF))
        lru_t2 = af.sh_tick[node] + c2 - b2i(fndF)
        lru_f2 = af.sh_tick[node] + c2

        def tier_fill(tag, lru, arrays, idx, st, th, touch_lru, way,
                      fill_c, vals, fill_lru, trash):
            """Touch + victim + fill on one (already-dropped) tier: the
            LRU touch refresh, then the packed install at the victim way
            — direct per-field scatters so the round scan updates the
            carried arrays in place."""
            wt = jnp.where(th, way, trash)
            lru = lru.at[idx, st, wt].set(
                jnp.where(th, touch_lru, lru[idx, st, wt]))
            vic = S.victim(tag, lru, idx, st)
            evicted = fill_c & (tag[idx, st, vic] != S.INVALID)
            wf = jnp.where(fill_c, vic, trash)

            def put(a, v):
                return a.at[idx, st, wf].set(
                    jnp.where(fill_c, v, a[idx, st, wf]))

            outs = [put(a, v) for a, v in arrays]
            return put(tag, vals), put(lru, fill_lru), outs, evicted

        sh_tag2, sh_lru2, (sh_wts2, sh_rts2, sh_ver2, sh_gseq2), evF = \
            tier_fill(sh_tag, af.sh.lru,
                      [(af.sh.wts, nwA), (af.sh.rts, nrA),
                       (af.sh.ver, mver), (af.sh_gseq, mgs)],
                      nodes, s2, th2, lru_t2, way2, fndF, kids, lru_f2, W2)
        rp_tag2, rp_lru2, (rp_wts2, rp_rts2, rp_ver2, rp_gseq2), ev1 = \
            tier_fill(rp_tag, af.rp.lru,
                      [(af.rp.wts, nw1), (af.rp.rts, nr1),
                       (af.rp.ver, resp_ver), (af.rp_gseq, resp_gs)],
                      reps, s1, th1, lru_t1, way1, resp_found, kids,
                      lru_f1, W1)

        # ---- counters: the scan's per-read gv/rv calls, summed per round
        n = lambda b: jnp.sum(b2i(b))
        b12, b2m, big = S.link_bytes(n(miss), n(need_mm),
                                     n(need_mm & home_miss))
        g2 = af.g + gsum(
            reads=n(act), l1_hits=n(h1), l2_hits=n(h2), l1_to_l2=n(miss),
            coh_miss_l1=n(coh), coh_miss_l2=n(coh2),
            self_invalidations=n(coh) + n(coh2), compulsory=n(comp),
            l2_to_mm=n(need_mm), pcie_blocks=n(need_mm & home_miss),
            refetches=n(resp_found), overflow_reinits=n(ovf),
            capacity_evictions=n(evF) + n(ev1),
            bytes_l1_l2=b12, bytes_l2_mm=b2m, bytes_inter_gpu=big)
        r2 = af.r.at[rep].add(rsum(
            reads=n(act), l1_hits=n(h1), l2_hits=n(h2), l1_to_l2=n(miss),
            coh_miss_l1=n(coh), coh_miss_l2=n(coh2),
            self_invalidations=n(coh) + n(coh2), compulsory=n(comp),
            refetches=n(resp_found),
            capacity_evictions=n(evF) + n(ev1)))

        af = af._replace(
            rp=af.rp._replace(tag=rp_tag2, wts=rp_wts2, rts=rp_rts2,
                              ver=rp_ver2, lru=rp_lru2),
            rp_gseq=rp_gseq2,
            rp_tick=af.rp_tick.at[rep].add(
                jnp.sum(b2i(th1) + b2i(resp_found))),
            sh=af.sh._replace(tag=sh_tag2, wts=sh_wts2, rts=sh_rts2,
                              ver=sh_ver2, lru=sh_lru2),
            sh_gseq=sh_gseq2,
            sh_tick=af.sh_tick.at[node].add(jnp.sum(b2i(th2) + b2i(fndF))),
            tsu=tsu2, g=g2, r=r2)

        vals = jnp.stack([
            b2i(h1 | resp_found),
            jnp.where(h1, hit_ver, jnp.where(resp_found, resp_ver, -1)),
            jnp.where(h1, hit_gs, jnp.where(resp_found, resp_gs, -1)),
            jnp.where(h1, 0, jnp.where(h2, 1, jnp.where(fndF, 2, 3))),
            jnp.where(fndF, mwts, 0), jnp.where(fndF, mrts, 0),
            b2i(fndF)])                               # RES_FIELDS order
        return af, jnp.where(act[None, :], vals, out)

    def pass_(af, kids, s1, s2, shard, masks, rep, node, rd, wr):
        out0 = jnp.zeros((len(RES_FIELDS), kids.shape[0]), i32)

        def step(carry, act):
            af, out = carry
            return round_body(af, out, act, kids, s1, s2, shard, rep,
                              node, rd, wr), None

        (af, out), _ = jax.lax.scan(step, (af, out0), masks)
        return af, out

    return pass_


# -------------------------------------------------- collective accounting
def collective_counts(jaxpr) -> dict:
    """Walk a (closed) jaxpr and count collective primitives: ``total``
    occurrences and how many sit inside a scan/while body (``in_loop``).
    A collective inside a loop executes once PER ITERATION — the exact
    O(ops)-collectives failure mode the batched pipeline removes — so the
    parity suite pins ``in_loop == 0`` and ``total`` == the per-batch
    collective budget for ``pipeline="batched"``.  (The miss pass's round
    scan is collective-free: its one gather sits OUTSIDE the scan.)

    The walker itself now lives in ``repro.obs.xprof`` (the observability
    layer's static cost probe, which also reports per-primitive counts
    and compiled FLOPs/bytes); this wrapper keeps the parity suite's
    two-field view."""
    from repro.obs.xprof import jaxpr_collectives

    c = jaxpr_collectives(jaxpr)
    return {"total": c["total"], "in_loop": c["in_loop"]}
