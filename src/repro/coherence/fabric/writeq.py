"""Async write-through queue with bounded in-flight writes.

HALCONE's writes are POSTED: the writer does not stall for the MM round trip
(engine.py's write_lat has no mm term).  The host-side analogue is this
queue: ``submit`` enqueues the write-through and returns immediately; drains
happen in FIFO order whenever more than ``max_in_flight`` writes are
outstanding, on ``flush``, or at a ``fence``.

A fence is the kernel boundary (engine trace op 3): every queued write
reaches the TSU, then every attached clock jumps to the global maximum cts —
after the fence, no reader can be served a pre-fence version under an old
lease it already held only because its clock lagged.

``max_in_flight=0`` degenerates to synchronous write-through (the legacy
``kv_lease`` behavior, and what the adapters use).
"""
from __future__ import annotations

import collections
from typing import Any, Callable, Deque, NamedTuple, Optional

from repro.coherence.fabric.tsu import LeaseGrant, TSUFabric


class _Pending(NamedTuple):
    key: Any
    value: Any
    on_complete: Optional[Callable[[LeaseGrant], None]]
    wr_lease: Optional[int]
    home_shard: Optional[int]


class WriteQueue:
    def __init__(self, fabric: TSUFabric, max_in_flight: Optional[int] = None):
        self.fabric = fabric
        self.max_in_flight = (fabric.cfg.max_in_flight
                              if max_in_flight is None else max_in_flight)
        self._q: Deque[_Pending] = collections.deque()
        fabric.attach_queue(self)

    def __len__(self) -> int:
        return len(self._q)

    def submit(self, key, value,
               on_complete: Optional[Callable[[LeaseGrant], None]] = None,
               *, wr_lease: Optional[int] = None,
               home_shard: Optional[int] = None) -> None:
        self._q.append(_Pending(key, value, on_complete, wr_lease, home_shard))
        while len(self._q) > self.max_in_flight:
            self._drain_one()

    def _drain_one(self) -> None:
        p = self._q.popleft()
        grant = self.fabric.write(p.key, p.value, wr_lease=p.wr_lease,
                                  home_shard=p.home_shard)
        if p.on_complete is not None:
            p.on_complete(grant)

    def flush(self) -> None:
        while self._q:
            self._drain_one()

    def fence(self) -> int:
        """Flush + kernel-boundary clock jump (delegates to the fabric, which
        drains every attached queue before moving the clocks)."""
        return self.fabric.barrier()
