"""Batched serving with the lease-coherent prefix cache: identical prompts
hit the HALCONE-style lease cache instead of re-prefilling.

    PYTHONPATH=src python examples/serve_batched.py
"""
import jax
import numpy as np

from repro import configs as cfgs
from repro.models import init_model
from repro.runtime.server import Request, Server


def main():
    cfg = cfgs.SMOKE["smollm-360m"]
    params = init_model(cfg, jax.random.PRNGKey(0))
    srv = Server(cfg, params, batch_size=2, max_len=64)
    rng = np.random.default_rng(0)
    prompt = rng.integers(2, cfg.vocab, 12).astype(np.int32)
    reqs = [Request(rid=i, prompt=prompt, max_new=6) for i in range(6)]
    out = srv.serve(reqs)
    for rid in sorted(out):
        print(f"request {rid}: {list(out[rid])}")
    print("prefix-cache stats:", srv.cache_stats)
    assert srv.cache_stats["hits"] >= 1
    print("OK: repeated prompt batches served from the lease cache")


if __name__ == "__main__":
    main()
