"""Backend-parity suite: the array-native fabric is BIT-IDENTICAL to the
host-object fabric (DESIGN.md §7), and the mesh-sharded fabric to both
(DESIGN.md §8).

Randomized op traces (reads/writes/fences/authority ops across replicas,
including forced 16-bit overflow reinits and TSU victim evictions) are
applied to both ``FabricBackend`` implementations; every observable must
match exactly: per-op results (values + versions), the ordered MM grant
log (wts/rts/version), the full FabricStats block (including the Fig-10
per-link byte counters), each replica's mirror counters, and the per-key
``memts`` clocks.  A hypothesis layer fuzzes the same property when
hypothesis is installed (CI does; the ``[test]`` extra pulls it in).

``ShardedArrayFabric`` runs the same suite on a REAL multi-device mesh:
the ``test_sharded_parity_forced_8_devices`` harness re-launches this
module's ``_sharded_multidevice_check`` in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (or runs it
in-process when the session already has 8+ devices, as CI's forced-mesh
job does), pinning sharded-vs-host AND sharded-vs-single-device equality
with one TSU shard per device and grants travelling over collectives.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.coherence.fabric import (ArrayFabric, FabricConfig, HostFabric,
                                    Op, ShardedArrayFabric)
from repro.core import protocol
from repro.core.state import BLOCK_BYTES

# one small geometry reused everywhere so the jitted op-scan compiles once
SMALL = dict(n_shards=2, rd_lease=8, wr_lease=4, tsu_capacity=4,
             shared_sets=4, shared_ways=2, replica_sets=2, replica_ways=2,
             max_in_flight=2)
# near-TS_MAX leases + a 2-entry TSU: every few ops trigger the 16-bit
# overflow reinit or a victim eviction
OVERFLOW = dict(n_shards=1, rd_lease=protocol.TS_MAX // 2, wr_lease=20000,
                tsu_capacity=2, shared_sets=2, shared_ways=1,
                replica_sets=1, replica_ways=2, max_in_flight=0)

KEYS = [f"k{i}" for i in range(8)]


def random_trace(rng, n_ops, n_replicas, wr_choices=(None,), n_nodes=2):
    ops = []
    for t in range(n_ops):
        r = int(rng.integers(n_replicas))
        k = KEYS[int(rng.integers(len(KEYS)))]
        c = rng.random()
        wl = wr_choices[int(rng.integers(len(wr_choices)))]
        if c < 0.45:
            ops.append(Op("read", k, replica=r))
        elif c < 0.8:
            ops.append(Op("write", k, f"v{t}", replica=r, wr_lease=wl))
        elif c < 0.85:
            ops.append(Op("fence"))
        elif c < 0.9:
            ops.append(Op("mm_write", k, f"m{t}", wr_lease=wl))
        elif c < 0.95:
            ops.append(Op("publish", k, f"p{t}",
                          node=int(rng.integers(n_nodes))))
        else:
            ops.append(Op("mm_read", k))
    return ops


def build_pair(cfg_kw, n_nodes=2, replicas_per_node=2):
    cfg = FabricConfig(**cfg_kw)
    return (HostFabric(cfg, n_nodes=n_nodes,
                       replicas_per_node=replicas_per_node),
            ArrayFabric(cfg, n_nodes=n_nodes,
                        replicas_per_node=replicas_per_node))


def assert_equivalent(host, arr, ops):
    hres = host.apply(ops)
    ares = arr.apply(ops)
    for i, ((op, hr), (_, ar)) in enumerate(zip(hres, ares)):
        assert hr == ar, f"op {i} ({op.kind} {op.key!r}): {hr!r} != {ar!r}"
    assert host.grant_log == arr.grant_log, "MM grant logs diverged"
    assert host.stats() == arr.stats(), "FabricStats diverged"
    for r in range(host.n_replicas):
        assert host.replica_stats(r) == arr.replica_stats(r), \
            f"replica {r} mirror counters diverged"
    for k in KEYS:
        assert host.memts(k) == arr.memts(k), f"memts({k!r}) diverged"


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_differential_random_trace(seed):
    host, arr = build_pair(SMALL)
    ops = random_trace(np.random.default_rng(seed), 350, 4)
    assert_equivalent(host, arr, ops)


@pytest.mark.parametrize("seed", [0, 1])
def test_differential_overflow_reinit_and_tsu_eviction(seed):
    """Forced 16-bit wraps + constant victim eviction in a 2-entry TSU."""
    host, arr = build_pair(OVERFLOW, n_nodes=1, replicas_per_node=2)
    ops = random_trace(np.random.default_rng(seed), 250, 2,
                       wr_choices=(None, 1, 30000), n_nodes=1)
    assert_equivalent(host, arr, ops)
    assert host.stats()["overflow_reinits"] > 0, "overflow never triggered"
    assert host.stats()["tsu_evictions"] > 0, "eviction never triggered"


def test_read_batch_two_phase_parity():
    """The batched read contract (hits vectorized first, misses in order)
    produces identical results, stats and mirrors on both backends."""
    host, arr = build_pair(SMALL)
    rng = np.random.default_rng(7)
    warm = random_trace(rng, 120, 4)
    host.apply(warm)
    arr.apply(warm)
    batch = [KEYS[int(rng.integers(len(KEYS)))] for _ in range(32)]
    batch.append("never-written")       # unknown key exercises phase 2
    assert host.read_batch(batch, replica=1) == arr.read_batch(batch,
                                                               replica=1)
    assert host.stats() == arr.stats()
    assert host.replica_stats(1) == arr.replica_stats(1)


def test_fast_path_equals_scan_path_on_all_hit_batch():
    """Phase 1 (one vectorized tier_probe) is bit-identical to the op-scan
    on an all-hit batch — results, counters, and the full device state."""
    import jax

    a1 = ArrayFabric(FabricConfig(**SMALL), n_nodes=1, replicas_per_node=1)
    a2 = ArrayFabric(FabricConfig(**SMALL), n_nodes=1, replicas_per_node=1)
    keys = KEYS[:4]
    for b in (a1, a2):
        for k in keys:
            b.write(k, f"{k}@0")
        b.fence()
    r1 = a1.read_batch(keys)                                  # fast path
    r2 = [x for _, x in a2.apply([Op("read", k) for k in keys])]
    assert r1 == r2
    assert a1.fast_read_batches == 1
    assert a1.stats() == a2.stats()
    for x, y in zip(jax.tree_util.tree_leaves(a1._af),
                    jax.tree_util.tree_leaves(a2._af)):
        assert (np.asarray(x) == np.asarray(y)).all()


# ------------------------------------------------------- sharded fabric
def test_sharded_fabric_parity_on_host_mesh():
    """ShardedArrayFabric is a FabricBackend and bit-identical to the host
    oracle through the shard_map entry point on whatever mesh this host
    has (1 device here; the 8-device variant runs in a subprocess)."""
    cfg = FabricConfig(**SMALL)
    host = HostFabric(cfg, n_nodes=2, replicas_per_node=2)
    sh = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    assert cfg.n_shards % sh.n_shard_devices == 0
    ops = random_trace(np.random.default_rng(3), 200, 4)
    assert_equivalent(host, sh, ops)


def test_sharded_rejects_indivisible_mesh():
    from repro.launch.mesh import make_fabric_mesh
    mesh = make_fabric_mesh()                      # all devices, 1 axis
    if int(mesh.devices.size) == 1:
        pytest.skip("single-device mesh divides everything")
    with pytest.raises(ValueError, match="divisible"):
        ShardedArrayFabric(FabricConfig(
            n_shards=int(mesh.devices.size) + 1, tsu_capacity=4), mesh=mesh)


def _keys_by_shard(cfg, want, prefix="t"):
    """First key hashing to each wanted shard (stable_hash routing)."""
    from repro.coherence.fabric import stable_hash
    out = {}
    i = 0
    while len(out) < len(want):
        k = f"{prefix}{i}"
        s = stable_hash(k) % cfg.n_shards
        if s in want and s not in out:
            out[s] = k
        i += 1
    return out


def test_cross_shard_reads_count_inter_gpu_bytes():
    """The Fig-10 pin: an MM access routed to a NON-home TSU shard moves
    BLOCK_BYTES over the inter-GPU link; a home-shard access moves none —
    and both backends account it identically."""
    cfg = FabricConfig(n_shards=2, tsu_capacity=8)
    by_shard = _keys_by_shard(cfg, {0, 1})
    for fab in (HostFabric(cfg, n_nodes=1, replicas_per_node=1),
                ArrayFabric(cfg, n_nodes=1, replicas_per_node=1)):
        # node 0's home shard is 0 (node_id % n_shards)
        fab.mm_write(by_shard[0], "local")         # authority preload
        fab.mm_write(by_shard[1], "remote")
        base = fab.stats()["bytes_inter_gpu"]
        assert fab.read(by_shard[0], replica=0) is not None
        assert fab.stats()["bytes_inter_gpu"] == base, \
            "shard-local read must not touch the inter-GPU link"
        assert fab.read(by_shard[1], replica=0) is not None
        assert fab.stats()["bytes_inter_gpu"] == base + BLOCK_BYTES, \
            "cross-shard read must move exactly one block inter-GPU"
        st = fab.stats()
        assert st["bytes_l1_l2"] == st["l1_to_l2"] * BLOCK_BYTES
        assert st["bytes_l2_mm"] == st["l2_to_mm"] * BLOCK_BYTES
        assert st["bytes_inter_gpu"] == st["pcie_blocks"] * BLOCK_BYTES
        assert st["inval_msgs"] == 0               # the paper's claim


def _sharded_multidevice_check():
    """Body of the forced-8-device parity check (run in-process when the
    session already has >= 8 devices, else via the subprocess harness):
    ShardedArrayFabric-vs-HostFabric and sharded-vs-single-device equality
    — results, grant log, stats incl. traffic counters, replica mirrors —
    with one TSU shard per device, plus the overflow/eviction config."""
    import jax

    assert len(jax.devices()) >= 8, "needs the forced 8-device host mesh"
    cfg_kw = dict(SMALL, n_shards=8)
    cfg = FabricConfig(**cfg_kw)
    host = HostFabric(cfg, n_nodes=2, replicas_per_node=2)
    sh = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    assert sh.n_shard_devices == 8                 # one shard per device
    ops = random_trace(np.random.default_rng(11), 220, 4)
    assert_equivalent(host, sh, ops)

    arr = ArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    arr.apply(ops)
    batch = [KEYS[i % len(KEYS)] for i in range(24)] + ["missing-key"]
    assert sh.read_batch(batch, replica=1) == arr.read_batch(batch,
                                                             replica=1)
    assert sh.stats() == arr.stats()
    assert list(sh.grant_log) == list(arr.grant_log)
    for r in range(sh.n_replicas):
        assert sh.replica_stats(r) == arr.replica_stats(r)
    assert sh.stats()["bytes_inter_gpu"] > 0       # the mesh saw real hops

    # overflow reinits + TSU victim evictions through the sharded path
    ocfg = dict(OVERFLOW, n_shards=2)
    host2 = HostFabric(FabricConfig(**ocfg), n_nodes=1, replicas_per_node=2)
    sh2 = ShardedArrayFabric(FabricConfig(**ocfg), n_nodes=1,
                             replicas_per_node=2)
    assert sh2.n_shard_devices == 2
    ops2 = random_trace(np.random.default_rng(12), 150, 2,
                        wr_choices=(None, 1, 30000), n_nodes=1)
    assert_equivalent(host2, sh2, ops2)
    assert host2.stats()["overflow_reinits"] > 0
    return True


def test_sharded_parity_forced_8_devices():
    """Run ``_sharded_multidevice_check`` on an 8-device host mesh: in
    process if this session was launched with the forced flag (CI), else
    in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    import jax

    if len(jax.devices()) >= 8:
        assert _sharded_multidevice_check()
        return
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), os.path.join(repo, "tests")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-c",
         "from test_fabric_parity import _sharded_multidevice_check; "
         "assert _sharded_multidevice_check(); print('SHARDED-PARITY-OK')"],
        env=env, cwd=repo, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"forced-8-device parity subprocess failed:\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    assert "SHARDED-PARITY-OK" in proc.stdout


def test_single_transition_layer():
    """Acceptance pin: both consumers import the rules from core.state."""
    from repro.coherence.fabric import arrays
    from repro.core import engine, state
    assert engine.S is state
    assert arrays.S is state


# ---------------------------------------------------------------- fuzzing
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # CI installs it via the [test] extra
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _op = st.one_of(
        st.tuples(st.just("read"), st.integers(0, 3),
                  st.sampled_from(KEYS)),
        st.tuples(st.just("write"), st.integers(0, 3),
                  st.sampled_from(KEYS)),
        st.tuples(st.just("fence"), st.just(0), st.just(KEYS[0])),
        st.tuples(st.just("mm_write"), st.just(0), st.sampled_from(KEYS)),
        st.tuples(st.just("publish"), st.integers(0, 1),
                  st.sampled_from(KEYS)),
        st.tuples(st.just("mm_read"), st.just(0), st.sampled_from(KEYS)),
    )

    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(_op, min_size=1, max_size=60))
    def test_hypothesis_differential(trace):
        host, arr = build_pair(SMALL)
        ops = []
        for t, (kind, idx, key) in enumerate(trace):
            if kind == "fence":
                ops.append(Op("fence"))
            elif kind == "publish":
                ops.append(Op("publish", key, f"p{t}", node=idx))
            elif kind in ("mm_write", "write"):
                ops.append(Op(kind, key, f"v{t}", replica=idx))
            else:
                ops.append(Op(kind, key, replica=idx))
        assert_equivalent(host, arr, ops)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_differential():
        pass
