"""Model assembly for all 10 architectures.

A config is compiled into a *plan*: an optional prefix of looped layers plus a
``lax.scan`` over stacked pattern-repeats (so HLO size / compile time are
independent of depth: qwen1.5-110b's 80 layers scan as cheaply as mamba2's 24).
Heterogeneous stacks (gemma3 5-local:1-global, llama4 dense/MoE interleave,
zamba2 mamba+shared-attn) scan over multi-layer pattern bodies.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import chunked_xent, rmsnorm, swiglu
from repro.models.params import P, abstract, materialize, shardings, stack_specs
from repro.sharding import NOSHARD, ShardCtx


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    kind: str          # dense | moe | ssm | attn_shared
    window: int = 0


@dataclasses.dataclass(frozen=True)
class Segment:
    mode: str                      # "scan" | "loop"
    pattern: Tuple[LayerDesc, ...]
    repeats: int                   # scan: >=1; loop: always 1


def build_plan(cfg: ModelConfig) -> List[Segment]:
    descs = [LayerDesc(cfg.layer_kind(i), cfg.attn_window(i))
             for i in range(cfg.n_layers)]
    prefix = cfg.first_dense
    segs: List[Segment] = []
    if prefix:
        segs.append(Segment("loop", tuple(descs[:prefix]), 1))
    rest = descs[prefix:]
    n = len(rest)
    period = n
    for p in range(1, min(16, n) + 1):
        reps = n // p
        if reps >= 2 and all(rest[i] == rest[i % p] for i in range(p * reps)):
            period = p
            break
    reps = n // period
    if reps >= 2:
        segs.append(Segment("scan", tuple(rest[:period]), reps))
        rem = rest[period * reps:]
        if rem:
            segs.append(Segment("loop", tuple(rem), 1))
    elif n:
        segs.append(Segment("loop", tuple(rest), 1))
    return segs


# ------------------------------------------------------------------ specs
def _attn_spec(cfg):
    return attn_mod.mla_spec(cfg) if cfg.is_mla else attn_mod.gqa_spec(cfg)


def block_spec(cfg: ModelConfig, desc: LayerDesc) -> dict:
    D = cfg.d_model
    ln = lambda: P((D,), (None,), "zeros")
    if desc.kind == "ssm":
        return {"ln": ln(), "ssm": ssm_mod.ssm_spec(cfg)}
    if desc.kind == "attn_shared":
        return {}                                     # weights live at top level
    s = {"ln1": ln(), "attn": _attn_spec(cfg), "ln2": ln()}
    if desc.kind == "moe":
        s["moe"] = moe_mod.moe_spec(cfg)
    else:
        s["mlp"] = {
            "wg": P((D, cfg.d_ff), ("embed", "mlp")),
            "wi": P((D, cfg.d_ff), ("embed", "mlp")),
            "wo": P((cfg.d_ff, D), ("mlp", "embed")),
        }
    return s


def shared_block_spec(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    return {
        "ln1": P((D,), (None,), "zeros"),
        "attn": _attn_spec(cfg),
        "ln2": P((D,), (None,), "zeros"),
        "mlp": {
            "wg": P((D, cfg.d_ff), ("embed", "mlp")),
            "wi": P((D, cfg.d_ff), ("embed", "mlp")),
            "wo": P((cfg.d_ff, D), ("mlp", "embed")),
        },
    }


def model_spec(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab
    spec: dict = {"embed": P((V, D), ("vocab", "embed"))}
    if cfg.frontend == "audio":
        spec["frontend"] = P((cfg.d_frontend, D), (None, "embed"))
    segs = build_plan(cfg)
    seg_specs = {}
    for si, seg in enumerate(segs):
        body = {str(j): block_spec(cfg, d) for j, d in enumerate(seg.pattern)}
        if seg.mode == "scan":
            body = stack_specs(body, seg.repeats)
        seg_specs[f"seg{si}"] = body
    spec["segments"] = seg_specs
    if any(d.kind == "attn_shared" for s in segs for d in s.pattern):
        spec["shared_attn"] = shared_block_spec(cfg)
    spec["ln_f"] = P((D,), (None,), "zeros")
    if not cfg.tie_embeddings:
        spec["unembed"] = P((D, V), ("embed", "vocab"))
    return spec


# ------------------------------------------------------------------ caches
def block_cache_spec(cfg: ModelConfig, desc: LayerDesc, batch: int,
                     max_len: int, seq_axis: str) -> dict:
    if desc.kind == "ssm":
        return ssm_mod.ssm_cache_spec(cfg, batch)
    if cfg.is_mla:
        return attn_mod.mla_cache_spec(cfg, batch, max_len, seq_axis)
    return attn_mod.gqa_cache_spec(cfg, batch, max_len, seq_axis)


def cache_spec(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    # long-context (batch==1): shard the KV sequence dim over "data"
    seq_axis = "kv_seq" if batch == 1 else "seq"
    segs = build_plan(cfg)
    out = {}
    for si, seg in enumerate(segs):
        body = {str(j): block_cache_spec(cfg, d, batch, max_len, seq_axis)
                for j, d in enumerate(seg.pattern)}
        if seg.mode == "scan":
            body = stack_specs(body, seg.repeats)
        out[f"seg{si}"] = body
    return out


# ------------------------------------------------------------------ forward
def _constrain_params(bp, specs, ctx: ShardCtx, compute_dtype):
    """Per-layer slice of scanned params: constrain + cast to compute dtype.
    Float >=2D weights are cast (halves FSDP all-gather bytes); norm scales
    and 1D biases stay in param dtype for numerics."""
    def leaf(x, spec: P):
        # cast FIRST so the FSDP all-gather and the gradient reduction both
        # move compute-dtype (bf16) bytes, then pin the sharding on the
        # casted value (its cotangent inherits the constraint)
        y = x
        if x.ndim >= 2 and jnp.issubdtype(x.dtype, jnp.floating):
            y = y.astype(compute_dtype)
        return ctx.constrain(y, *spec.axes)

    return jax.tree.map(leaf, bp, specs)


def _apply_block(cfg: ModelConfig, desc: LayerDesc, bp: dict, h, *,
                 positions, cache, pos, shared_attn, ctx: ShardCtx):
    aux = jnp.zeros((), jnp.float32)
    if desc.kind == "ssm":
        y, nc = ssm_mod.ssm_apply(cfg, bp["ssm"],
                                  rmsnorm(h, bp["ln"], cfg.rms_eps),
                                  cache=cache, ctx=ctx)
        return ctx.constrain(h + y, "batch", "seq_shard", None), nc, aux
    p = shared_attn if desc.kind == "attn_shared" else bp
    apply_fn = attn_mod.mla_apply if cfg.is_mla else attn_mod.gqa_apply
    a, nc = apply_fn(cfg, p["attn"], rmsnorm(h, p["ln1"], cfg.rms_eps),
                     positions=positions, cache=cache, pos=pos,
                     window=desc.window, ctx=ctx)
    h = ctx.constrain(h + a, "batch", "seq_shard", None)
    hn = rmsnorm(h, p["ln2"], cfg.rms_eps)
    if desc.kind == "moe":
        m, aux = moe_mod.moe_apply(cfg, bp["moe"], hn, ctx)
    else:
        m = swiglu(hn, p["mlp"]["wg"], p["mlp"]["wi"], p["mlp"]["wo"], h.dtype)
    return ctx.constrain(h + m, "batch", "seq_shard", None), nc, aux


def forward(cfg: ModelConfig, params: dict, tokens, *, patches=None,
            frames=None, cache=None, pos=None, ctx: ShardCtx = NOSHARD):
    """Returns (h_final [B,S,D], new_cache, aux_loss)."""
    cd = cfg.policy.compute_dtype
    if frames is not None:
        h = (frames.astype(cd) @ params["frontend"].astype(cd))
        B, S = frames.shape[:2]
    else:
        B, S = tokens.shape
        h = params["embed"].astype(cd)[tokens]
    if patches is not None:
        npatch = patches.shape[1]
        h = jnp.concatenate([patches.astype(cd), h[:, npatch:]], axis=1)
    h = ctx.constrain(h, "batch", "seq_shard", None)
    positions = (jnp.arange(S) if pos is None else pos + jnp.arange(S))

    segs = build_plan(cfg)
    shared_attn = params.get("shared_attn")
    new_cache: Dict[str, Any] = {}
    aux_total = jnp.zeros((), jnp.float32)

    for si, seg in enumerate(segs):
        sp = params["segments"][f"seg{si}"]
        sc = None if cache is None else cache[f"seg{si}"]
        if seg.mode == "loop":
            ncs = {}
            for j, desc in enumerate(seg.pattern):
                bc = None if sc is None else sc[str(j)]
                h, nc, aux = _apply_block(cfg, desc, sp[str(j)], h,
                                          positions=positions, cache=bc,
                                          pos=pos, shared_attn=shared_attn,
                                          ctx=ctx)
                aux_total = aux_total + aux
                ncs[str(j)] = {} if nc is None else nc
            new_cache[f"seg{si}"] = ncs
        else:
            seg_specs = {str(j): block_spec(cfg, d)
                         for j, d in enumerate(seg.pattern)}

            def body(carry, xs):
                hh, aux_acc = carry
                bp, bc = xs
                # Constrain per-layer param slices to their target sharding:
                # the transpose of with_sharding_constraint constrains the
                # cotangents too, so XLA reduce-scatters per-layer grads
                # instead of all-reducing them (x40 collective reduction on
                # qwen110-class FSDP; EXPERIMENTS.md §Perf).  Casting to the
                # compute dtype BEFORE use halves all-gather wire bytes.
                bp = _constrain_params(bp, seg_specs, ctx, cd)
                ncs = {}
                for j, desc in enumerate(seg.pattern):
                    blk_c = None if bc is None else bc[str(j)]
                    hh, nc, aux = _apply_block(cfg, desc, bp[str(j)], hh,
                                               positions=positions,
                                               cache=blk_c, pos=pos,
                                               shared_attn=shared_attn,
                                               ctx=ctx)
                    aux_acc = aux_acc + aux
                    ncs[str(j)] = {} if nc is None else nc
                return (hh, aux_acc), ncs

            if cfg.policy.remat:
                body = jax.checkpoint(body, prevent_cse=False)
            xs = (sp, sc)
            if sc is None:
                # scan requires matching pytrees; use params-only xs
                def body_np(carry, bp):
                    return body(carry, (bp, None))
                (h, aux_total), ys = jax.lax.scan(body_np, (h, aux_total), sp)
            else:
                (h, aux_total), ys = jax.lax.scan(body, (h, aux_total), xs)
            new_cache[f"seg{si}"] = ys if sc is not None else {}

    h = rmsnorm(h, params["ln_f"], cfg.rms_eps)
    return h, (new_cache if cache is not None else None), aux_total


def unembed_matrix(cfg: ModelConfig, params: dict):
    if cfg.tie_embeddings:
        return params["embed"].T
    return params["unembed"]


def loss_fn(cfg: ModelConfig, params: dict, batch: dict,
            ctx: ShardCtx = NOSHARD):
    """Training loss. batch: tokens/labels [B,S] (+patches/frames)."""
    h, _, aux = forward(cfg, params, batch.get("tokens"),
                        patches=batch.get("patches"),
                        frames=batch.get("frames"), ctx=ctx)
    W = unembed_matrix(cfg, params)
    if cfg.causal and "labels" not in batch:
        hh = h                                    # h[t] predicts tokens[t+1]
        ll = jnp.roll(batch["tokens"], -1, axis=1)
        mask = jnp.ones_like(ll, jnp.float32).at[:, -1].set(0.0)
    else:
        hh, ll = h, batch["labels"]
        mask = batch.get("mask")
        if mask is None:
            mask = jnp.ones_like(ll, jnp.float32)
    ce = chunked_xent(hh, W, ll, mask)
    return ce + 0.01 * aux, {"ce": ce, "aux": aux}


# ------------------------------------------------------------------ serving
def prefill(cfg: ModelConfig, params: dict, tokens, cache, *,
            patches=None, frames=None, ctx: ShardCtx = NOSHARD):
    """Fill the cache from a prompt; returns (next_token_ids [B], cache)."""
    h, new_cache, _ = forward(cfg, params, tokens, patches=patches,
                              frames=frames, cache=cache, pos=None, ctx=ctx)
    logits = (h[:, -1:] @ unembed_matrix(cfg, params).astype(h.dtype))
    next_ids = jnp.argmax(logits.astype(jnp.float32), axis=-1)[:, 0]
    return next_ids, new_cache


def decode_step(cfg: ModelConfig, params: dict, cache, tokens, pos,
                ctx: ShardCtx = NOSHARD):
    """One decode step. tokens: [B, 1]; pos: scalar int32 (cache fill level)."""
    h, new_cache, _ = forward(cfg, params, tokens, cache=cache, pos=pos,
                              ctx=ctx)
    logits = (h[:, -1:] @ unembed_matrix(cfg, params).astype(h.dtype))
    next_ids = jnp.argmax(logits.astype(jnp.float32), axis=-1)[:, 0]
    return next_ids, new_cache


# ------------------------------------------------------------------ builders
def init_model(cfg: ModelConfig, key):
    return materialize(model_spec(cfg), key, cfg.policy.param_dtype)


def abstract_model(cfg: ModelConfig):
    return abstract(model_spec(cfg), cfg.policy.param_dtype)


def model_shardings(cfg: ModelConfig, mesh, rules=None):
    return shardings(model_spec(cfg), mesh, cfg.policy.param_dtype, rules)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, key=None):
    return materialize(cache_spec(cfg, batch, max_len), jax.random.PRNGKey(0),
                       cfg.policy.cache_dtype)


def abstract_cache(cfg: ModelConfig, batch: int, max_len: int):
    return abstract(cache_spec(cfg, batch, max_len), cfg.policy.cache_dtype)
