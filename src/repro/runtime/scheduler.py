"""Continuous deadline-driven batch formation + open-loop trace replay
(ISSUE 9 tentpole, part 2).

``Server`` and every fabric bench form FIXED-SIZE waves: the next batch
exists only when enough requests are already in hand, so a trickle of
arrivals either starves waiting for the wave to fill or is served in
tiny batches that waste the one-collective grant pipeline.  This module
replaces that with **admit-by-deadline** formation driven by a
``loadgen.RequestTrace``'s arrival timestamps:

  * requests accumulate in an arrival queue;
  * a wave fires when it reaches ``max_batch`` (full fire) OR when the
    oldest queued request has waited ``max_wait_s`` (deadline fire) —
    under ``mode="fixed"`` only full fires happen (plus one final
    partial wave when the stream ends), which is exactly the old
    fixed-size-wave behavior, kept as the measured baseline;
  * in-flight waves overlap through the fabric's existing
    ``read_batch_async`` boundary with ``serve_stream``'s schedule —
    wave N+1 is FORMED (admission bookkeeping, host work) while wave N's
    device batch is in flight, and N resolves before N+1 dispatches, so
    at most one handle is ever outstanding and the backend's ordering
    contract (resolve before the next write/fence) holds by
    construction;
  * formed waves are padded onto POW2 SHAPE BUCKETS
    (``max(min_bucket, next_pow2(b))``, pads cycle the wave's own keys,
    pad results discarded) so variable batch sizes never touch the
    jit recompile path — the fabric's phase-1 probe is shape-specialized
    on the key-vector length (DESIGN.md §13).

The replay clock is VIRTUAL: it advances by the measured wall of each
fabric call and jumps across idle gaps, so a trace recorded at any rate
replays open-loop — arrivals land at trace time whether or not the
fabric keeps up, and per-request latency = resolve time − arrival time
measures queueing honestly (the closed-loop drivers can't).  Passing
``service_model`` replaces measured walls with a deterministic cost
function — replays become exactly reproducible (tests, and the
continuous-vs-fixed property is provable there rather than flaky).

``form_waves`` is the Server integration: the same firing rules applied
arrival-only (no service feedback), yielding variable-size waves for
``Server.serve_stream`` in place of its fixed-size grouping.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.runtime.loadgen import RequestTrace


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Wave-formation policy.

    mode        "continuous" (max-batch OR deadline fires) or "fixed"
                (full waves only + one final partial — the old Server
                behavior, the measured baseline)
    max_batch   wave size cap (a full queue fires immediately)
    max_wait_s  deadline budget: the oldest queued request never waits
                longer than this before its wave fires (continuous only)
    bucket      pad waves onto pow2 shape buckets (recompile-free)
    min_bucket  smallest bucket (matches the fabric's apply() floor)
    """

    mode: str = "continuous"
    max_batch: int = 64
    max_wait_s: float = 5e-3
    bucket: bool = True
    min_bucket: int = 8

    def __post_init__(self):
        if self.mode not in ("continuous", "fixed"):
            raise ValueError(f"unknown mode {self.mode!r}")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")


def pad_to_bucket(keys: Sequence, policy: BatchPolicy) -> List:
    """Pad a formed wave to its pow2 shape bucket by cycling the wave's
    own keys (no new keys → no spurious compulsory misses); callers
    discard the pad rows' results."""
    if not policy.bucket or not keys:
        return list(keys)
    m = max(policy.min_bucket, _next_pow2(len(keys)))
    return [keys[j % len(keys)] for j in range(m)]


@dataclasses.dataclass
class ReplayResult:
    """One open-loop replay: per-request latencies + wave telemetry +
    the exact served event stream (for the Fig-10 engine decomposition)."""

    latency_s: np.ndarray         # [n] seconds, resolve − arrival
    t_end: float                  # virtual makespan (last resolve)
    batch_sizes: List[int]        # real (pre-pad) wave sizes
    padded_sizes: List[int]       # bucketed sizes actually probed
    fires: Dict[str, int]         # full / deadline / final counts
    walls: Dict[str, float]       # dispatch / resolve / republish seconds
    events: List[Tuple]           # ("read", kids) | ("write", kids) |
                                  # ("fence",) in served order, pads incl.

    @property
    def n_requests(self) -> int:
        return len(self.latency_s)

    def goodput(self, slo_s: float) -> Tuple[int, float]:
        """(# completions meeting the SLO, attained fraction)."""
        ok = int(np.sum(self.latency_s <= slo_s))
        return ok, ok / max(len(self.latency_s), 1)


def replay(backend, trace: RequestTrace, policy: BatchPolicy, *,
           replica: int = 1, writer: int = 0,
           key_of: Optional[Callable[[int], str]] = None,
           republish_every: int = 0, republish_n: int = 16,
           service_model: Optional[Callable[[int], float]] = None,
           ) -> ReplayResult:
    """Replay ``trace`` open-loop against a ``FabricBackend``.

    A model-refresh write storm (``republish_n`` keys round-robin) +
    fence precedes the first wave and then every ``republish_every``
    SERVED REQUESTS — the outstanding read handle resolves first
    (ordering contract), and the republish keeps reader leases churning
    so replayed traffic carries real per-link bytes for the Fig-10
    decomposition instead of a pure replica-tier hit stream.  The
    cadence is per-request, not per-wave, on purpose: continuous mode
    fires more, smaller waves than fixed mode at the same offered load,
    and a per-wave cadence would bill it proportionally more storm
    overhead — an unfair comparison between the two policies.

    ``service_model(padded_size) -> seconds`` makes the virtual clock
    deterministic (fabric calls still execute; only their time charge is
    modeled).  Default: measured wall clock.
    """
    key_of = key_of or (lambda k: f"prefix/{k}")
    t_arr, kids, n = trace.t, trace.kid, len(trace)
    q: collections.deque = collections.deque()   # admitted request indices
    i = 0                                        # next unadmitted arrival
    now = 0.0
    done = np.full(n, np.nan)
    pending: Optional[Tuple[List[int], object]] = None
    events: List[Tuple] = []
    batch_sizes: List[int] = []
    padded_sizes: List[int] = []
    fires = {"full": 0, "deadline": 0, "final": 0}
    walls = {"dispatch_s": 0.0, "resolve_s": 0.0, "republish_s": 0.0}
    n_waves = served = next_storm_at = n_storms = 0

    def timed(fn, modeled: float) -> float:
        t0 = time.perf_counter()
        fn()
        w = time.perf_counter() - t0
        return w if service_model is None else modeled

    def admit() -> None:
        nonlocal i
        while i < n and t_arr[i] <= now:
            q.append(i)
            i += 1

    def resolve_pending() -> None:
        nonlocal pending, now
        members, handle = pending
        w = timed(handle.result, 0.0)
        now += w
        walls["resolve_s"] += w
        for r in members:
            done[r] = now
        pending = None

    def try_fire() -> Optional[Tuple[List[int], str]]:
        if not q:
            return None
        if len(q) >= policy.max_batch:
            kind = "full"
        elif (policy.mode == "continuous"
              and now - t_arr[q[0]] >= policy.max_wait_s - 1e-12):
            kind = "deadline"
        elif i >= n and pending is None:
            kind = "final"                       # end-of-stream drain
        else:
            return None
        take = min(len(q), policy.max_batch)
        return [q.popleft() for _ in range(take)], kind

    def next_fire_time() -> Optional[float]:
        """Earliest virtual time a wave can fire, absent service."""
        cands = []
        if policy.mode == "continuous":
            if q:
                cands.append(t_arr[q[0]] + policy.max_wait_s)
            elif i < n:
                cands.append(t_arr[i] + policy.max_wait_s)
        need = policy.max_batch - len(q)
        if i + need - 1 < n:
            cands.append(t_arr[i + need - 1])    # the wave-filling arrival
        elif i < n:
            cands.append(t_arr[n - 1])           # last arrival → final drain
        return min(cands) if cands else None

    while True:
        admit()
        fired = try_fire()
        if fired is None:
            if pending is not None:
                resolve_pending()                # drain the in-flight wave
                continue
            nft = next_fire_time()
            if nft is None:
                break
            now = max(now, nft)                  # idle: jump the clock
            continue
        members, kind = fired
        fires[kind] += 1
        if republish_every and served >= next_storm_at:
            if pending is not None:
                resolve_pending()                # handle before write/fence
            sl = [(n_storms * republish_n + j)
                  % trace.n_keys for j in range(republish_n)]
            w = timed(
                lambda: (backend.write_batch(
                    [(key_of(k), f"v@{n_waves}") for k in sl],
                    replica=writer), backend.fence()),
                service_model(len(sl)) if service_model else 0.0)
            now += w
            walls["republish_s"] += w
            events.append(("write", sl))
            events.append(("fence",))
            n_storms += 1
            next_storm_at += republish_every
        ks = [int(kids[r]) for r in members]
        padded = pad_to_bucket(ks, policy)
        if pending is not None:
            resolve_pending()                    # N resolves before N+1
        holder = {}
        w = timed(
            lambda: holder.update(h=backend.read_batch_async(
                [key_of(k) for k in padded], replica=replica)),
            service_model(len(padded)) if service_model else 0.0)
        now += w
        walls["dispatch_s"] += w
        events.append(("read", list(padded)))
        batch_sizes.append(len(ks))
        padded_sizes.append(len(padded))
        pending = (members, holder["h"])
        n_waves += 1
        served += len(members)
    if pending is not None:
        resolve_pending()

    assert not np.isnan(done).any(), "replay lost requests"
    return ReplayResult(latency_s=done - t_arr, t_end=now,
                        batch_sizes=batch_sizes, padded_sizes=padded_sizes,
                        fires=fires, walls=walls, events=events)


def form_waves(t_arrive: Sequence[float], items: Sequence,
               policy: BatchPolicy) -> List[List]:
    """Arrival-driven wave formation only (no service feedback): group
    timestamped ``items`` into waves under the policy's firing rules.
    This is the ``Server`` integration — feed the result straight to
    ``Server.serve_stream`` in place of fixed-size request waves (the
    stream path pads each wave into decode groups itself and tolerates
    empty/partial/non-pow2 waves, pinned in tests/test_overlap_stream)."""
    t = np.asarray(t_arrive, np.float64)
    if len(t) != len(items):
        raise ValueError("t_arrive and items length mismatch")
    if len(t) and np.any(np.diff(t) < 0):
        raise ValueError("arrival timestamps must be nondecreasing")
    waves: List[List] = []
    q: collections.deque = collections.deque()
    i, n, now = 0, len(items), 0.0
    while i < n or q:
        while i < n and t[i] <= now:
            q.append(i)
            i += 1
        if len(q) >= policy.max_batch:
            waves.append([items[q.popleft()]
                          for _ in range(policy.max_batch)])
            continue
        if q and ((policy.mode == "continuous"
                   and now - t[q[0]] >= policy.max_wait_s - 1e-12)
                  or i >= n):
            waves.append([items[q.popleft()] for _ in range(len(q))])
            continue
        cands = []
        if policy.mode == "continuous":
            if q:
                cands.append(t[q[0]] + policy.max_wait_s)
            elif i < n:                  # next arrival's own deadline —
                cands.append(t[i] + policy.max_wait_s)   # never skip it
        need = policy.max_batch - len(q)
        cands.append(t[min(i + need - 1, n - 1)])
        now = max(now, min(cands))
    return waves
