"""Logical-axis sharding rules with divisibility fallback.

Params and activations are annotated with *logical* axis names; this module maps
them onto the physical mesh.  A mesh axis is silently dropped for a tensor dim
whose size is not divisible by the axis size (e.g. smollm's 15 heads on a 16-way
"model" axis, hubert's vocab=504), guaranteeing that every produced
``NamedSharding`` is valid for every architecture in the pool.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# logical axis -> preferred mesh axes (tried in order, greedily combined)
DEFAULT_RULES: dict = {
    "batch": ("pod", "data"),
    "tokens": ("pod", "data"),     # flattened B*S (MoE dispatch)
    "embed": ("pod", "data"),      # ZeRO-3 / FSDP for parameter d_model dims
    "heads": ("model",),
    "kv_heads": ("model",),
    "mlp": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "expert_mlp": ("model",),
    "capacity": ("data",),
    "seq": (),                     # unsharded by default
    "seq_shard": ("model",),       # sequence parallelism for residual carries
    "kv_seq": ("data",),           # long-context decode: shard KV length
    "dstate": (),
    "stack": (),                   # scanned layer dim — never sharded
    "fabric_shard": ("fabric",),   # TSU shard-major dims of the coherence
                                   # fabric (launch.mesh.make_fabric_mesh)
    None: (),
}


def _axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def shard_map(f, mesh: Mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = True):
    """``jax.shard_map`` with a fallback for jax<0.5 (this container's
    0.4.x), where the API lives in jax.experimental with ``auto``/
    ``check_rep`` instead of ``axis_names``/``check_vma``."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, axis_names=axis_names,
                             check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = (frozenset(mesh.axis_names) - set(axis_names)
            if axis_names is not None else frozenset())
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma, auto=auto)


def mesh_axes_for(
    mesh: Mesh,
    dim_size: int,
    logical: Optional[str],
    rules: Optional[dict] = None,
    taken: Optional[set] = None,
) -> Tuple[str, ...]:
    """Greedy: keep prefix of preferred mesh axes while divisibility holds."""
    rules = rules or DEFAULT_RULES
    prefs = rules.get(logical, ())
    out = []
    size = 1
    for ax in prefs:
        if ax not in mesh.axis_names:
            continue
        if taken is not None and ax in taken:
            continue
        nxt = size * _axis_size(mesh, ax)
        if nxt == 0 or dim_size % nxt != 0:
            break
        out.append(ax)
        size = nxt
    return tuple(out)


def partition_spec(
    mesh: Mesh,
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    rules: Optional[dict] = None,
) -> PartitionSpec:
    """Build a PartitionSpec; each mesh axis used at most once per tensor."""
    assert len(shape) == len(logical_axes), (shape, logical_axes)
    taken: set = set()
    spec = []
    for dim, logical in zip(shape, logical_axes):
        axes = mesh_axes_for(mesh, dim, logical, rules, taken)
        taken.update(axes)
        if not axes:
            spec.append(None)
        elif len(axes) == 1:
            spec.append(axes[0])
        else:
            spec.append(tuple(axes))
    # trim trailing Nones
    while spec and spec[-1] is None:
        spec.pop()
    return PartitionSpec(*spec)


def named_sharding(
    mesh: Mesh,
    shape: Sequence[int],
    logical_axes: Sequence[Optional[str]],
    rules: Optional[dict] = None,
) -> NamedSharding:
    return NamedSharding(mesh, partition_spec(mesh, shape, logical_axes, rules))


class ShardCtx:
    """Threaded through model code; no-ops when mesh is None (CPU smoke tests)."""

    def __init__(self, mesh: Optional[Mesh] = None, rules: Optional[dict] = None):
        self.mesh = mesh
        self.rules = rules or DEFAULT_RULES

    def constrain(self, x, *logical_axes):
        if self.mesh is None:
            return x
        sh = named_sharding(self.mesh, x.shape, logical_axes, self.rules)
        return jax.lax.with_sharding_constraint(x, sh)

    def spec(self, shape, logical_axes) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return named_sharding(self.mesh, shape, logical_axes, self.rules)


NOSHARD = ShardCtx(None)


def rules_without(*axes) -> dict:
    """Rules with given mesh axes removed (e.g. inside a shard_map manual
    region, where constraints may not reference Manual axes)."""
    out = {}
    for k, v in DEFAULT_RULES.items():
        out[k] = tuple(a for a in v if a not in axes)
    return out
