"""Fig 9: Xtreme stress suite — SM-WT-C-HALCONE vs SM-WT-NC across vector
sizes.  Paper: worst-case degradation 14.3% (X1) / 12.1% (X2) / 16.8% (X3)
at 192 KB vectors, shrinking toward ~0.6% as capacity misses take over."""
import numpy as np

from benchmarks.common import cached, emit, timed
from repro.core import simulate
from repro.core.sysconfig import sm_wt_halcone, sm_wt_nc
from repro.core.traces import XtremeSpec, xtreme

# (blocks_per_slice, reps, label) — 128 CUs => vector = slice*128*64B,
# so 24 blocks/slice = the paper's smallest 192KB vectors
SIZES = [(24, 10, "192KB"), (96, 4, "768KB"), (384, 2, "3MB")]
SYS = dict(n_gpus=4, cus_per_gpu=32)


def run_all(force=False):
    def compute():
        out = {}
        for variant in (1, 2, 3):
            out[f"xtreme{variant}"] = {}
            for nb, reps, label in SIZES:
                spec = XtremeSpec(variant, nb, reps)
                base = sm_wt_halcone(**SYS)
                ops, addrs = xtreme(base, spec)
                rh, us = timed(simulate, sm_wt_halcone(**SYS), ops, addrs)
                rn, _ = timed(simulate, sm_wt_nc(**SYS), ops, addrs)
                slow = float(rh["cycles"]) / float(rn["cycles"]) - 1
                out[f"xtreme{variant}"][label] = {
                    "slowdown_pct": slow * 100, "us": us,
                    "coh_miss_l1": float(rh["counters"]["coh_miss_l1"]),
                }
        return out

    return cached("fig9_xtreme", compute, force)


def main(force=False):
    data = run_all(force)
    worst = 0.0
    for variant, sizes in data.items():
        for label, rec in sizes.items():
            emit(f"fig9/{variant}/{label}", rec["us"],
                 f"halcone_slowdown={rec['slowdown_pct']:.1f}%")
            worst = max(worst, rec["slowdown_pct"])
    emit("fig9/worst_case", 0.0, f"slowdown={worst:.1f}% (paper: 16.8%)")
    return data


if __name__ == "__main__":
    main()
