"""Jit'd public wrappers for the Pallas kernels.

``use_pallas('tpu'|'interpret'|'off')`` selects the execution path: on real
TPUs the kernels compile natively; on CPU they run in interpret mode (tests)
or fall back to the jnp references (the dry-run lowering path)."""
from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _decode
from repro.kernels.flash_attention import flash_attention as _flash
from repro.kernels.lease_probe import lease_probe as _lease_probe
from repro.kernels.rmsnorm import rmsnorm as _rmsnorm
from repro.kernels.ssd_chunk import ssd_chunk as _ssd_chunk
from repro.kernels.tier_pass import miss_round as _miss_round
from repro.kernels.tier_pass import write_grant as _write_grant

_MODE = "interpret"


def use_pallas(mode: str):
    """mode: 'tpu' | 'interpret' | 'off'."""
    global _MODE
    assert mode in ("tpu", "interpret", "off")
    _MODE = mode


def _interp() -> bool:
    return _MODE != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, **kw):
    if _MODE == "off":
        return ref.attention_ref(q, k, v, causal=causal, window=window)
    return _flash(q, k, v, causal=causal, window=window,
                  interpret=_interp(), **kw)


def decode_attention(q, k, v, kv_len, **kw):
    if _MODE == "off":
        return ref.attention_ref(q, k, v, causal=False, kv_len=kv_len)
    return _decode(q, k, v, kv_len, interpret=_interp(), **kw)


def rmsnorm(x, w, *, eps=1e-6, **kw):
    if _MODE == "off":
        return ref.rmsnorm_ref(x, w, eps)
    return _rmsnorm(x, w, eps=eps, interpret=_interp(), **kw)


def ssd_chunk(x, dt, A, Bc, Cc, **kw):
    return _ssd_chunk(x, dt, A, Bc, Cc, interpret=_interp(), **kw)


def lease_probe(tag_rows, rts_rows, cts, addr, mwts, mrts, **kw):
    if _MODE == "off":
        return ref.lease_probe_ref(tag_rows, rts_rows, cts, addr, mwts, mrts)
    return _lease_probe(tag_rows, rts_rows, cts, addr, mwts, mrts,
                        interpret=_interp(), **kw)


def miss_round(*args, **kw):
    if _MODE == "off":
        return ref.miss_round_ref(*args)
    return _miss_round(*args, interpret=_interp(), **kw)


def write_grant(*args, **kw):
    if _MODE == "off":
        return ref.write_grant_ref(*args)
    return _write_grant(*args, interpret=_interp(), **kw)
