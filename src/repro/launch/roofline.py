"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips x peak FLOP/s)
memory term     = HLO_bytes / (chips x HBM bw)
collective term = wire bytes / (chips x link bw)

FLOPs/bytes come from ``compiled.cost_analysis()`` (per-device, post-SPMD).
Collective bytes are NOT in cost_analysis: we parse ``compiled.as_text()`` and
sum wire bytes for every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute, with ring-algorithm factors per op kind.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

# TPU v5e-class chip constants (per the brief)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # B/s per chip
ICI_BW = 50e9                # B/s per link (per-chip injection, 1 link)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute|"
    r"collective-broadcast)"
    r"(?:-start|-done)?\(", re.M)
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{.*?\}\}|\[[\d,]+\]<=\[\d+\])")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(1, len([x for x in first.split(",") if x.strip() != ""]))
    dims = [int(x) for x in g[1:g.index("]")].split(",")]
    total = int(g[g.index("<=[") + 3:-1])
    n_groups = dims[0] if len(dims) > 1 else 1
    return max(1, total // max(n_groups, 1)) if len(dims) > 1 else dims[0]


def _wire_bytes(kind: str, result_bytes: int, n: int) -> float:
    """Per-device wire bytes under ring algorithms."""
    if n <= 1:
        return 0.0
    f = (n - 1) / n
    if kind == "all-gather":
        return result_bytes * f                  # result = gathered buffer
    if kind == "all-reduce":
        return 2.0 * result_bytes * f            # reduce-scatter + all-gather
    if kind == "reduce-scatter":
        return result_bytes * (n - 1)            # result = scattered shard
    if kind == "all-to-all":
        return result_bytes * f
    return float(result_bytes)                   # permute / broadcast


def parse_collectives(hlo_text: str, n_devices: int) -> Dict:
    per_kind: Dict[str, float] = {}
    ops: List[dict] = []
    seen_done = set()
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        line = hlo_text[m.start():hlo_text.find("\n", m.start())]
        if "-done(" in line:        # async pair: count only the start
            continue
        rb = _shape_bytes(type_str)
        n = _group_size(line, n_devices)
        wb = _wire_bytes(kind, rb, n)
        per_kind[kind] = per_kind.get(kind, 0.0) + wb
        ops.append({"kind": kind, "result_bytes": rb, "group": n,
                    "wire_bytes": wb})
    return {"per_kind": per_kind,
            "total_wire_bytes": sum(per_kind.values()),
            "n_ops": len(ops),
            "largest": sorted(ops, key=lambda o: -o["wire_bytes"])[:12]}


@dataclasses.dataclass
class Roofline:
    flops: float                  # per device
    hbm_bytes: float              # per device
    wire_bytes: float             # per device
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float = 0.0      # 6*N*D (or 6*N_active*D)
    useful_ratio: float = 0.0


def roofline_terms(flops: float, hbm_bytes: float, wire_bytes: float,
                   model_flops_global: float = 0.0,
                   n_devices: int = 1) -> Roofline:
    tc = flops / PEAK_FLOPS
    tm = hbm_bytes / HBM_BW
    tx = wire_bytes / ICI_BW
    terms = {"compute": tc, "memory": tm, "collective": tx}
    bn = max(terms, key=terms.get)
    mf = model_flops_global / max(n_devices, 1)
    return Roofline(flops=flops, hbm_bytes=hbm_bytes, wire_bytes=wire_bytes,
                    t_compute=tc, t_memory=tm, t_collective=tx, bottleneck=bn,
                    model_flops=mf,
                    useful_ratio=(mf / flops if flops else 0.0))


def model_flops_for(cfg, cell, n_params_total: int, n_params_active: int) -> float:
    """6*N*D for a train step (fwd+bwd), 2*N*D for inference, per the usual
    transformer accounting; D = tokens processed this step."""
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_params_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_params_active * tokens
    tokens = cell.global_batch                      # one token per sequence
    return 2.0 * n_params_active * tokens
