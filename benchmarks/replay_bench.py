"""Open-loop replay serving bench: continuous deadline-driven batching
vs fixed-size waves, SLO-gated goodput (ISSUE 9 tentpole, part 3).

Every other row in this repo's trajectory files is CLOSED-LOOP: the next
batch is formed only after the previous one returns, so latency is
measured relative to the driver's own previous batch, never relative to
an arrival deadline.  This bench replays an arrival-timestamped
``loadgen`` trace open-loop through ``runtime/scheduler.replay`` on the
default fabric (the mesh-placed ``ShardedArrayFabric`` under CI's forced
8-device host mesh) and reports what a serving operator would:

  sweep      >= 3 offered-load points (fractions of the measured
             closed-loop capacity), each replaying the IDENTICAL key
             stream (``RequestTrace.scaled`` rescales the time axis
             only) under BOTH formation policies — continuous
             (admit-by-deadline) and fixed-size waves (the old Server
             behavior) — with p50/p95/p99 latency (obs histogram,
             exact percentiles) + goodput (completions meeting the SLO).

  headline   at the saturating point (offered = measured capacity, the
             diurnal peaks push 1.9x over it) continuous beats fixed on
             goodput: fixed waves starve the batch during diurnal
             troughs (fill time >> SLO) while the deadline budget bounds
             the continuous wait.  ``continuous_over_fixed`` is CI-gated
             against the committed trajectory like ``sharded_over_single``.

  fig10      the replayed traffic (reads + the periodic republish
             storms, pads included — the exact served event stream) is
             decomposed per link against the engine's Fig-10 prediction
             for the SAME key stream: ``inval_msgs`` must match
             bit-for-bit (zero — HALCONE sends none, in the simulator
             and in production) and each side's per-link bytes must
             satisfy the shared accounting identity
             (``core.state.link_bytes``: data blocks x BLOCK_BYTES,
             invalidations x CTRL_BYTES).  Raw message counts differ by
             modeled geometry (2-CU engine vs replica/shared tiers) and
             are reported side by side.

Results land in benchmarks/artifacts AND the root-level
``BENCH_serving.json`` (the serving-path perf trajectory; ``_meta``
records shards/devices/sha/jax like BENCH_fabric.json).

    PYTHONPATH=src python benchmarks/replay_bench.py [--mini] [--force]
    XLA_FLAGS=--xla_force_host_platform_device_count=8 ... # CI's mesh
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

_REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(_REPO / "src"))
sys.path.insert(0, str(_REPO))        # `from benchmarks import common`
                                      # when invoked as a script (CI)

from repro.coherence.fabric import FabricConfig, default_fabric  # noqa: E402
from repro.core import engine  # noqa: E402
from repro.core.state import BLOCK_BYTES, CTRL_BYTES  # noqa: E402
from repro.core.sysconfig import sm_wt_halcone  # noqa: E402
from repro.obs import LatencyHistogram  # noqa: E402
from repro.runtime import loadgen, scheduler  # noqa: E402

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_serving.json"

# x measured full-wave capacity; the last point is the saturating one:
# its diurnal peak (1.9 x 0.7 = 1.33x measured capacity) drives the
# pipeline past saturation while its trough (0.1x the mean) starves
# fixed-size waves — the regime where batch-formation policy, not raw
# throughput, decides goodput.  Pushing the MEAN to ~capacity instead
# makes goodput capacity-bound for both policies (the standing peak
# backlog keeps even fixed waves full) and the comparison degenerates
# into service-wall noise, which gates nothing.
LOAD_FACTORS = (0.25, 0.5, 0.7)
REPUBLISH_EVERY_WAVES = 4             # storm cadence: every 4 FULL waves'
REPUBLISH_N = 16                      # worth of served requests


def _key(k: int) -> str:
    return f"prefix/{k}"


def build_fabric() -> object:
    cfg = FabricConfig(n_shards=8, rd_lease=8, wr_lease=4,
                       replica_sets=1024, replica_ways=8,
                       shared_sets=2048, shared_ways=8)
    return default_fabric(cfg, n_nodes=2, replicas_per_node=2)


def warm(fab, n_keys: int, policy: scheduler.BatchPolicy) -> float:
    """Publish the key space and compile every shape the replay touches
    BEFORE anything is timed: each pow2 wave bucket, the republish storm
    + fence drain, and the post-republish miss-pass buckets (the ISSUE 9
    percentile-hygiene rule: no compile wall inside a timed section)."""
    t0 = time.time()
    keys = [_key(k) for k in range(n_keys)]
    fab.write_batch([(k, f"{k}@0") for k in keys], replica=0)
    fab.fence()
    fab.read_batch(keys, replica=1)              # fill the replica tier
    b = policy.min_bucket
    top = max(policy.min_bucket, scheduler._next_pow2(policy.max_batch))
    while b <= top:
        # republish + fence + two reads per bucket: compiles the bucket's
        # probe shape AND its miss-pass (M, R) buckets, then its all-hit
        # fast path.  The storm slice MUST overlap the keys the warm read
        # probes (keys[:b] here) — a disjoint slice leaves the warm read
        # all-hit and the bucket's miss pass uncompiled, and the first
        # post-storm partial wave of the sweep then eats an ~O(10 s)
        # compile wall inside its timed section
        sl = [j % n_keys for j in range(REPUBLISH_N)]
        fab.write_batch([(_key(k), f"w@{b}") for k in sl], replica=0)
        fab.fence()
        # a pad-degenerate wave (one request cycled across the whole
        # bucket — what a deadline-fired singleton looks like) carries a
        # conflict chain as deep as the bucket, which exceeds the round
        # budget and takes the op-scan fallback: compile it per bucket
        # too, on a missing key so the fallback actually runs
        fab.read_batch([keys[0]] * b, replica=1)     # deep-dup fallback
        fab.read_batch(keys[:b], replica=1)          # miss-heavy rounds
        fab.read_batch(keys[:b], replica=1)          # all-hit fast path
        b *= 2
    return time.time() - t0


def _mode_row(res: scheduler.ReplayResult, slo_s: float,
              offered_rps: float) -> dict:
    h = LatencyHistogram()
    h.record_many(res.latency_s.tolist())
    s = h.summary()
    ok, attain = res.goodput(slo_s)
    return {
        "count": s["count"],
        "p50_us": s["p50_us"], "p95_us": s["p95_us"],
        "p99_us": s["p99_us"], "max_us": s["max_us"],
        "goodput_rps": round(ok / max(res.t_end, 1e-9), 1),
        "slo_attain": round(attain, 4),
        "achieved_rps": round(res.n_requests / max(res.t_end, 1e-9), 1),
        "offered_rps": round(offered_rps, 1),
        "n_waves": len(res.batch_sizes),
        "mean_batch": round(float(np.mean(res.batch_sizes)), 1),
        "mean_padded": round(float(np.mean(res.padded_sizes)), 1),
        "fires": dict(res.fires),
        "walls_s": {k: round(v, 4) for k, v in res.walls.items()},
    }


# ------------------------------------------------- Fig-10 decomposition
def _engine_counters(n_keys: int, events) -> dict:
    """The engine's Fig-10 prediction for the served stream: replay the
    EXACT event sequence (reads, republish storms, fences — pads
    included) as a 2-CU SM-WT-HALCONE trace (reader CU on GPU0, writer
    CU on GPU1), and difference away the publish+warm prefix so the
    counters cover precisely the replayed traffic, like the fabric's
    stats delta does."""
    R, W = [], []                                # reader / writer columns
    Ra, Wa = [], []

    def emit(r_op, r_ad, w_op, w_ad):
        R.append(r_op); Ra.append(r_ad); W.append(w_op); Wa.append(w_ad)

    for k in range(n_keys):                      # publish
        emit(engine.NOP, 0, engine.WRITE, k)
    emit(engine.FENCE, 0, engine.FENCE, 0)
    for k in range(n_keys):                      # warm the reader tier
        emit(engine.READ, k, engine.NOP, 0)
    prefix_T = len(R)
    for ev in events:
        if ev[0] == "read":
            for k in ev[1]:
                emit(engine.READ, int(k), engine.NOP, 0)
        elif ev[0] == "write":
            for k in ev[1]:
                emit(engine.NOP, 0, engine.WRITE, int(k))
        else:                                    # fence
            emit(engine.FENCE, 0, engine.FENCE, 0)

    cfg = sm_wt_halcone(n_gpus=2, cus_per_gpu=1)
    ops = np.stack([np.asarray(R, np.int32), np.asarray(W, np.int32)])
    addrs = np.stack([np.asarray(Ra, np.int32), np.asarray(Wa, np.int32)])
    full = engine.simulate(cfg, ops, addrs)["counters"]
    pref = engine.simulate(cfg, ops[:, :prefix_T],
                           addrs[:, :prefix_T])["counters"]
    return {k: int(round(float(full[k]) - float(pref[k])))
            for k in engine.COUNTERS}


def _identity_ok(c: dict) -> bool:
    """The shared accounting identity (core.state.link_bytes)."""
    return (c["bytes_l1_l2"] == c["l1_to_l2"] * BLOCK_BYTES
            and c["bytes_l2_mm"] == c["l2_to_mm"] * BLOCK_BYTES
            and c["bytes_inter_gpu"] == (c["pcie_blocks"] * BLOCK_BYTES
                                         + c["inval_msgs"] * CTRL_BYTES))


def decompose(n_keys: int, events, fab_delta: dict) -> dict:
    """Per-link decomposition of the replayed traffic: production fabric
    vs engine prediction for the identical key stream.  Asserts the
    bit-for-bit inval match and both accounting identities — the bench
    fails, not just under-reports, if the claim breaks."""
    eng = _engine_counters(n_keys, events)
    fab = {k: int(fab_delta.get(k, 0)) for k in engine.COUNTERS}
    assert fab["inval_msgs"] == eng["inval_msgs"] == 0, (
        f"invalidation traffic appeared: fabric={fab['inval_msgs']} "
        f"engine={eng['inval_msgs']} (HALCONE sends none)")
    assert _identity_ok(fab), f"fabric byte-accounting identity broke: {fab}"
    assert _identity_ok(eng), f"engine byte-accounting identity broke: {eng}"
    rows = {}
    for link, msgs in (("bytes_l1_l2", "l1_to_l2"),
                       ("bytes_l2_mm", "l2_to_mm"),
                       ("bytes_inter_gpu", "pcie_blocks")):
        rows[link] = {"fabric_bytes": fab[link], "engine_bytes": eng[link],
                      "fabric_msgs": fab[msgs], "engine_msgs": eng[msgs],
                      "inval_bytes": 0}
    return {"links": rows,
            "inval_msgs": {"fabric": fab["inval_msgs"],
                           "engine": eng["inval_msgs"],
                           "bit_identical": True},
            "identity_ok": True,
            "n_events": len(events)}


# ------------------------------------------------------------- the sweep
def _stats_delta(after: dict, before: dict) -> dict:
    return {k: after[k] - before.get(k, 0) for k in after}


def run_sweep(mini: bool = False,
              trace: loadgen.RequestTrace = None) -> dict:
    n_keys = 64 if mini else 256
    n_req = 1800 if mini else 6000
    policy_kw = dict(max_batch=32 if mini else 64, min_bucket=8)

    if trace is None:
        trace = loadgen.synthesize(
            n_req, n_keys, a=1.2, process="diurnal", rate=1.0,
            amplitude=0.9, cycles=3.0, seed=7)
    else:
        n_keys, n_req = trace.n_keys, len(trace)

    fab = build_fabric()
    pol = scheduler.BatchPolicy(mode="continuous", **policy_kw)
    warm_s = warm(fab, n_keys, pol)
    # per-request storm cadence (see scheduler.replay: per-wave would
    # bill continuous mode more storms than fixed at equal load)
    republish_reqs = REPUBLISH_EVERY_WAVES * policy_kw["max_batch"]

    # closed-loop capacity: replay with every arrival at ~t=0 — all waves
    # fire full, so achieved rps IS the fabric's saturated service rate
    # (dispatch + resolve + its share of republish storms included).
    # Run twice and keep the second: the first pass absorbs the residual
    # first-touch walls (allocator, dispatch caches) that would otherwise
    # understate capacity and misplace every sweep point; it also leaves
    # both modes' sweeps fully shape-warm.
    for _ in range(2):
        cap_res = scheduler.replay(
            fab, trace.scaled(1e9), pol, republish_every=republish_reqs,
            republish_n=REPUBLISH_N)
    capacity_rps = cap_res.n_requests / max(cap_res.t_end, 1e-9)
    svc_wave_s = cap_res.t_end / max(len(cap_res.batch_sizes), 1)

    # deadline + SLO derive from the measured service quantum so the
    # bench is machine-independent: the continuous worst case (deadline
    # wait + ~2 service quanta) sits under the SLO, the fixed-wave
    # trough fill (max_batch / (0.1 x 0.9 x capacity) ≈ 11 quanta at
    # the saturating point's diurnal trough) sits well over it.
    max_wait_s = max(1.5 * svc_wave_s, 1e-3)
    slo_s = max_wait_s + 4.0 * svc_wave_s
    policies = {
        "continuous": scheduler.BatchPolicy(
            mode="continuous", max_wait_s=max_wait_s, **policy_kw),
        "fixed": scheduler.BatchPolicy(mode="fixed", **policy_kw),
    }

    sweep = []
    sat_events, sat_delta = None, None
    for factor in LOAD_FACTORS:
        target = factor * capacity_rps
        tr = trace.scaled(target / trace.offered_rps)
        point = {"offered_factor": factor,
                 "offered_rps": round(target, 1)}
        # the gated saturating point is measured best-of-2 per mode with
        # the trials INTERLEAVED (cont, fixed, cont, fixed): a transient
        # machine stall then degrades at most one trial of each mode
        # instead of landing wholesale on whichever policy happened to
        # run inside the noisy window — which would flip the gated ratio
        # on scheduler noise alone, not on formation policy
        trials = 2 if factor == LOAD_FACTORS[-1] else 1
        best = {}
        for _ in range(trials):
            for mode, p in policies.items():
                before = fab.stats()
                res = scheduler.replay(fab, tr, p,
                                       republish_every=republish_reqs,
                                       republish_n=REPUBLISH_N)
                delta = _stats_delta(fab.stats(), before)
                row = _mode_row(res, slo_s, target)
                if (mode not in best
                        or row["goodput_rps"] > best[mode][0]["goodput_rps"]):
                    best[mode] = (row, res.events, delta)
        for mode, (row, ev, delta) in best.items():
            point[mode] = row
            if factor == LOAD_FACTORS[-1] and mode == "continuous":
                sat_events, sat_delta = ev, delta
        point["continuous_over_fixed"] = round(
            point["continuous"]["goodput_rps"]
            / max(point["fixed"]["goodput_rps"], 1e-9), 3)
        sweep.append(point)

    sat = sweep[-1]
    out = {
        "sweep": sweep,
        "saturating": {
            "offered_factor": sat["offered_factor"],
            "offered_rps": sat["offered_rps"],
            "continuous_goodput_rps": sat["continuous"]["goodput_rps"],
            "fixed_goodput_rps": sat["fixed"]["goodput_rps"],
            "continuous_over_fixed": sat["continuous_over_fixed"],
            "continuous_p99_us": sat["continuous"]["p99_us"],
            "fixed_p99_us": sat["fixed"]["p99_us"],
        },
        "capacity_rps": round(capacity_rps, 1),
        "svc_wave_us": round(svc_wave_s * 1e6, 1),
        "slo_ms": round(slo_s * 1e3, 3),
        "max_wait_ms": round(max_wait_s * 1e3, 3),
        "warm_s": round(warm_s, 2),
        "policy": {"max_batch": policy_kw["max_batch"],
                   "min_bucket": policy_kw["min_bucket"],
                   "republish_every_reqs": republish_reqs,
                   "republish_n": REPUBLISH_N},
        "trace": {"n_requests": len(trace), "n_keys": trace.n_keys,
                  **{k: v for k, v in trace.meta.items()
                     if k != "scaled_by"}},
        "fig10_decomposition": decompose(n_keys, sat_events, sat_delta),
    }
    return out


def _bench_meta(fab_shards: int = 8) -> dict:
    import subprocess

    import jax

    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             cwd=pathlib.Path(__file__).parent,
                             timeout=10).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    return {
        "generated_by": "benchmarks/replay_bench.py",
        "git_sha": sha,
        "jax_version": jax.__version__,
        "device_kind": jax.devices()[0].platform,
        "n_devices": len(jax.devices()),
        "fabric_shards": fab_shards,
    }


def write_bench_json(serving: dict) -> None:
    blob = {"serving": serving, "_meta": _bench_meta()}
    BENCH_PATH.write_text(json.dumps(blob, indent=1))
    print(f"wrote {BENCH_PATH}", file=sys.stderr)


def _emit_rows(serving: dict) -> None:
    from benchmarks import common

    sat = serving["saturating"]
    common.emit("serving/replay_saturating",
                sat["continuous_p99_us"],
                f"continuous_over_fixed={sat['continuous_over_fixed']}x;"
                f"cont_goodput={sat['continuous_goodput_rps']};"
                f"fixed_goodput={sat['fixed_goodput_rps']};"
                f"capacity={serving['capacity_rps']}")
    for point in serving["sweep"]:
        c, f = point["continuous"], point["fixed"]
        common.emit(f"serving/replay_load_{point['offered_factor']}",
                    c["p99_us"],
                    f"cont_p99={c['p99_us']};fixed_p99={f['p99_us']};"
                    f"cont_attain={c['slo_attain']};"
                    f"fixed_attain={f['slo_attain']}")


def run(force: bool = False, mini: bool = False) -> None:
    """Harness entry point (benchmarks.run): cached sweep + CSV rows +
    the root-level BENCH_serving.json trajectory file."""
    from benchmarks import common

    serving = common.cached(
        "replay_bench_suite_mini" if mini else "replay_bench_suite",
        lambda: run_sweep(mini=mini), force=force)
    _emit_rows(serving)
    write_bench_json(serving)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mini", action="store_true",
                    help="CI footprint: small stream, 64 keys")
    ap.add_argument("--force", action="store_true",
                    help="recompute instead of using cached artifacts")
    ap.add_argument("--trace", type=pathlib.Path, default=None,
                    help="replay a recorded trace (loadgen npz) instead "
                         "of synthesizing one")
    ap.add_argument("--save-trace", type=pathlib.Path, default=None,
                    help="record the synthesized trace to PATH (npz) and "
                         "exit")
    args = ap.parse_args()

    if args.save_trace is not None:
        n_keys, n_req = (64, 1800) if args.mini else (256, 6000)
        tr = loadgen.synthesize(n_req, n_keys, a=1.2, process="diurnal",
                                rate=1.0, amplitude=0.9, cycles=3.0, seed=7)
        tr.save(args.save_trace)
        print(f"recorded {len(tr)} requests -> {args.save_trace}")
        return

    if args.trace is not None:
        serving = run_sweep(mini=args.mini,
                            trace=loadgen.RequestTrace.load(args.trace))
        _emit_rows(serving)
        write_bench_json(serving)
    else:
        run(force=args.force, mini=args.mini)
    blob = json.loads(BENCH_PATH.read_text())
    sat = blob["serving"]["saturating"]
    print(f"replay_bench: capacity={blob['serving']['capacity_rps']} rps, "
          f"saturating goodput continuous="
          f"{sat['continuous_goodput_rps']} vs fixed="
          f"{sat['fixed_goodput_rps']} rps "
          f"(continuous_over_fixed={sat['continuous_over_fixed']}x)",
          flush=True)


if __name__ == "__main__":
    main()
