"""The batched grant pipeline: `read_batch` phase 2 as vectorized passes.

PR 3's two-phase batched read served every replica-tier lease hit with ONE
vectorized probe (phase 1) but re-ran the miss subset through the exact
per-op scan — so a miss-heavy serving batch still paid one scan step (and,
sharded, one grant collective) per op.  This module completes the fast
path (ISSUE 5 tentpole, DESIGN.md §9): the whole miss subset is served by
a SECOND vectorized pass — one batched tier probe, one batched TSU grant
(``state.tsu_lease_batch``), one batched fill per tier — so a batch costs
O(tiers) array ops and, on the sharded fabric, ONE packed grant collective
instead of O(ops).

Bit-identity with the sequential oracle (`HostFabric`, and the
``pipeline="scan"`` op-scan) is preserved by executing the pass over
**conflict-free rounds**:

  * ``conflict_rounds`` splits the miss subset, in op order, into maximal
    contiguous segments in which no two ops share a key, a replica-tier
    set, or a shared-tier set.  Ops in one round touch disjoint cache
    state (distinct TSU entries — keys are distinct; distinct tier sets —
    so probes, victim choices and fills cannot observe each other), hence
    executing them simultaneously equals executing them sequentially.
  * The one piece of state every op shares — the per-store LRU tick — is
    reproduced exactly with prefix-sum rank math: op *i*'s touch writes
    ``tick0 + cumsum(touch+fill)[i] - fill[i]`` and its fill writes
    ``tick0 + cumsum(touch+fill)[i]``, the precise values the sequential
    scan would have written (see DESIGN.md §9 for the proof).

All rounds run inside ONE jitted ``lax.scan`` over the round masks (the
fabric state is the scan carry, so XLA updates it in place; per-op
results accumulate into one packed ``[7, M]`` buffer), and on the sharded
fabric the packed TSU buffer is assembled ONCE before the round scan —
the per-batch collective budget stays O(1) no matter how many rounds the
subset needs.

A serving batch (deduplicated keys, sets spread by ``stable_hash``) is a
single round; pathological batches degrade to a few rounds, and
``ArrayFabric.read_batch`` falls back to the op-scan beyond a small round
budget — ordering-sensitive debugging can force that path permanently
with ``pipeline="scan"``.

``make_miss_pass`` returns the pure pass; `arrays.py` owns jitting and the
mesh placement (packed-TSU ``owner_gather`` in, ``owner_take`` out).
``collective_counts`` walks a jaxpr and reports how many collectives it
contains and how many sit inside a scan/while loop — the parity suite's
O(1)-collectives-per-batch pin and the ``batched_grants`` benchmark row
both read it.
"""
from __future__ import annotations

import collections
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.coherence.fabric.stats import GI, G_KEYS, RI, R_KEYS
from repro.core import state as S
# the packed per-op result block ([7, M] int32) — the layout contract now
# lives in core.state so the simulator's round step emits the same record
# (re-exported here for existing consumers)
from repro.core.state import RES_FIELDS  # noqa: F401


def conflict_rounds(kids, s1, s2) -> List[np.ndarray]:
    """Split a miss subset (op order) into maximal contiguous conflict-free
    rounds: within a round all keys, replica sets and shared sets are
    distinct.  Returns index arrays into the subset; concatenated they are
    ``range(len(kids))`` — rounds never reorder ops, so committing them in
    round order IS the sequential op order."""
    rounds: List[np.ndarray] = []
    cur: List[int] = []
    seen_k, seen_1, seen_2 = set(), set(), set()
    for i, (k, a, b) in enumerate(zip(np.asarray(kids).tolist(),
                                      np.asarray(s1).tolist(),
                                      np.asarray(s2).tolist())):
        if k in seen_k or a in seen_1 or b in seen_2:
            rounds.append(np.asarray(cur, np.int64))
            cur = []
            seen_k, seen_1, seen_2 = set(), set(), set()
        cur.append(i)
        seen_k.add(k)
        seen_1.add(a)
        seen_2.add(b)
    rounds.append(np.asarray(cur, np.int64))
    return rounds


def round_masks(rounds: List[np.ndarray], n_rounds: int,
                width: int) -> np.ndarray:
    """Pack conflict rounds into a dense ``[n_rounds, width]`` bool mask
    matrix (rows beyond ``len(rounds)`` are empty — a fully masked pass is
    a no-op), the shape the one-jit round scan consumes."""
    masks = np.zeros((n_rounds, width), bool)
    for r, idxs in enumerate(rounds):
        masks[r, idxs] = True
    return masks


def make_miss_pass(W1: int, W2: int, KS: int):
    """Build the vectorized miss pass for one tier geometry (W1/W2 = tier
    way counts, i.e. the trash-way indices; KS = TSU shard count).

    The returned function has the signature
    ``pass_(af, kids, s1, s2, shard, masks, rep, node, rd, wr)
    -> (af, res)`` where ``af`` is the fabric state pytree (arrays._AF),
    kids/s1/s2/shard are [M] int32 op arrays (padded), ``masks`` is the
    [R, M] conflict-round matrix (each row one conflict-free round),
    rep/node are scalars (one replica per read_batch call), and ``res``
    is the packed [7, M] per-op result block (``RES_FIELDS`` order) of
    the op-scan's read path.

    The rounds run as ONE ``lax.scan`` with the fabric state as carry;
    each round body is the read path of ``arrays._build_run``'s step
    function re-expressed over a whole conflict-free round at once —
    every lease decision is the same ``core.state`` call the scan makes.
    """
    i32 = jnp.int32
    NG, NR = len(G_KEYS), len(R_KEYS)
    b2i = lambda b: b.astype(i32)

    def gsum(**kw):
        out = jnp.zeros((NG,), i32)
        return out.at[jnp.array([GI[k] for k in kw], i32)].add(
            jnp.stack(list(kw.values())))

    def rsum(**kw):
        out = jnp.zeros((NR,), i32)
        return out.at[jnp.array([RI[k] for k in kw], i32)].add(
            jnp.stack(list(kw.values())))

    def round_body(af, out, act, kids, s1, s2, shard, rep, node, rd, wr):
        M = kids.shape[0]
        z = jnp.zeros((M,), i32)
        reps = jnp.full((M,), rep, i32)
        nodes = jnp.full((M,), node, i32)

        # ---- replica probe (ReplicaCache.get): classify + self-invalidate
        th1, h1, way1, _, _, _, _ = S.tier_probe(af.rp, reps, s1, kids, z, z)
        th1, h1 = th1 & act, h1 & act
        hit_ver = af.rp.ver[reps, s1, way1]
        hit_gs = af.rp_gseq[reps, s1, way1]
        miss = act & ~h1
        coh = miss & th1
        comp = miss & ~th1
        w1d = jnp.where(coh, way1, W1)
        rp_tag = af.rp.tag.at[reps, s1, w1d].set(
            jnp.where(coh, S.INVALID, af.rp.tag[reps, s1, w1d]))

        # ---- shared probe (SharedCache.get, only on a replica miss)
        th2, h2, way2, _, _, _, _ = S.tier_probe(af.sh, nodes, s2, kids, z, z)
        th2, h2 = th2 & miss, h2 & miss
        sh_ver = af.sh.ver[nodes, s2, way2]
        sh_gs = af.sh_gseq[nodes, s2, way2]
        sh_wts = af.sh.wts[nodes, s2, way2]
        sh_rts = af.sh.rts[nodes, s2, way2]
        coh2 = miss & th2 & ~h2
        w2d = jnp.where(coh2, way2, W2)
        sh_tag = af.sh.tag.at[nodes, s2, w2d].set(
            jnp.where(coh2, S.INVALID, af.sh.tag[nodes, s2, w2d]))

        # ---- ONE batched TSU grant for the whole round (state rules)
        need_mm = miss & ~h2
        found, mwts, mrts, mver, mgs, ovf, tsu2 = S.tsu_lease_batch(
            af.tsu, af.tsu_ver, af.tsu_gseq, shard, kids, rd, wr, need_mm)
        fndF = need_mm & found
        home_miss = shard != node % KS

        # ---- response chain (what travels up to each tier)
        resp_found = h2 | fndF
        nwA, nrA, _ = S.install_lease(af.sh.cts[nodes], mwts, mrts)
        resp_ver = jnp.where(h2, sh_ver, mver)
        resp_gs = jnp.where(h2, sh_gs, mgs)
        resp_wts = jnp.where(h2, sh_wts, nwA)
        resp_rts = jnp.where(h2, sh_rts, nrA)
        nw1, nr1, _ = S.install_lease(af.rp.cts[reps], resp_wts, resp_rts)

        # ---- sequential tick math (the op-scan's exact LRU trajectory):
        # per op the touch bump precedes the install bump, so op i's touch
        # writes tick0 + c[i] - fill[i] and its install tick0 + c[i] with
        # c = cumsum(touch + fill) — prefix sums over op order.
        c1 = jnp.cumsum(b2i(th1) + b2i(resp_found))
        lru_t1 = af.rp_tick[rep] + c1 - b2i(resp_found)
        lru_f1 = af.rp_tick[rep] + c1
        c2 = jnp.cumsum(b2i(th2) + b2i(fndF))
        lru_t2 = af.sh_tick[node] + c2 - b2i(fndF)
        lru_f2 = af.sh_tick[node] + c2

        def tier_fill(tag, lru, arrays, idx, st, th, touch_lru, way,
                      fill_c, vals, fill_lru, trash):
            """Touch + victim + fill on one (already-dropped) tier: the
            LRU touch refresh, then the packed install at the victim way
            — direct per-field scatters so the round scan updates the
            carried arrays in place."""
            wt = jnp.where(th, way, trash)
            lru = lru.at[idx, st, wt].set(
                jnp.where(th, touch_lru, lru[idx, st, wt]))
            vic = S.victim(tag, lru, idx, st)
            evicted = fill_c & (tag[idx, st, vic] != S.INVALID)
            wf = jnp.where(fill_c, vic, trash)

            def put(a, v):
                return a.at[idx, st, wf].set(
                    jnp.where(fill_c, v, a[idx, st, wf]))

            outs = [put(a, v) for a, v in arrays]
            return put(tag, vals), put(lru, fill_lru), outs, evicted

        sh_tag2, sh_lru2, (sh_wts2, sh_rts2, sh_ver2, sh_gseq2), evF = \
            tier_fill(sh_tag, af.sh.lru,
                      [(af.sh.wts, nwA), (af.sh.rts, nrA),
                       (af.sh.ver, mver), (af.sh_gseq, mgs)],
                      nodes, s2, th2, lru_t2, way2, fndF, kids, lru_f2, W2)
        rp_tag2, rp_lru2, (rp_wts2, rp_rts2, rp_ver2, rp_gseq2), ev1 = \
            tier_fill(rp_tag, af.rp.lru,
                      [(af.rp.wts, nw1), (af.rp.rts, nr1),
                       (af.rp.ver, resp_ver), (af.rp_gseq, resp_gs)],
                      reps, s1, th1, lru_t1, way1, resp_found, kids,
                      lru_f1, W1)

        # ---- counters: the scan's per-read gv/rv calls, summed per round
        n = lambda b: jnp.sum(b2i(b))
        b12, b2m, big = S.link_bytes(n(miss), n(need_mm),
                                     n(need_mm & home_miss))
        g2 = af.g + gsum(
            reads=n(act), l1_hits=n(h1), l2_hits=n(h2), l1_to_l2=n(miss),
            coh_miss_l1=n(coh), coh_miss_l2=n(coh2),
            self_invalidations=n(coh) + n(coh2), compulsory=n(comp),
            l2_to_mm=n(need_mm), pcie_blocks=n(need_mm & home_miss),
            refetches=n(resp_found), overflow_reinits=n(ovf),
            capacity_evictions=n(evF) + n(ev1),
            bytes_l1_l2=b12, bytes_l2_mm=b2m, bytes_inter_gpu=big)
        r2 = af.r.at[rep].add(rsum(
            reads=n(act), l1_hits=n(h1), l2_hits=n(h2), l1_to_l2=n(miss),
            coh_miss_l1=n(coh), coh_miss_l2=n(coh2),
            self_invalidations=n(coh) + n(coh2), compulsory=n(comp),
            refetches=n(resp_found),
            capacity_evictions=n(evF) + n(ev1)))

        af = af._replace(
            rp=af.rp._replace(tag=rp_tag2, wts=rp_wts2, rts=rp_rts2,
                              ver=rp_ver2, lru=rp_lru2),
            rp_gseq=rp_gseq2,
            rp_tick=af.rp_tick.at[rep].add(
                jnp.sum(b2i(th1) + b2i(resp_found))),
            sh=af.sh._replace(tag=sh_tag2, wts=sh_wts2, rts=sh_rts2,
                              ver=sh_ver2, lru=sh_lru2),
            sh_gseq=sh_gseq2,
            sh_tick=af.sh_tick.at[node].add(jnp.sum(b2i(th2) + b2i(fndF))),
            tsu=tsu2, g=g2, r=r2)

        vals = jnp.stack([
            b2i(h1 | resp_found),
            jnp.where(h1, hit_ver, jnp.where(resp_found, resp_ver, -1)),
            jnp.where(h1, hit_gs, jnp.where(resp_found, resp_gs, -1)),
            jnp.where(h1, 0, jnp.where(h2, 1, jnp.where(fndF, 2, 3))),
            jnp.where(fndF, mwts, 0), jnp.where(fndF, mrts, 0),
            b2i(fndF)])                               # RES_FIELDS order
        return af, jnp.where(act[None, :], vals, out)

    def pass_(af, kids, s1, s2, shard, masks, rep, node, rd, wr):
        out0 = jnp.zeros((len(RES_FIELDS), kids.shape[0]), i32)

        def step(carry, act):
            af, out = carry
            return round_body(af, out, act, kids, s1, s2, shard, rep,
                              node, rd, wr), None

        (af, out), _ = jax.lax.scan(step, (af, out0), masks)
        return af, out

    return pass_


# ------------------------------------------------------ batched write pass
# The packed per-op result block of the write pass ([6, M] int32): each op
# is a posted write, so the only externally visible output is its drain —
# dcount (0/1) plus the drained grant's key/version/lease/gseq, exactly the
# op-scan's dlog_* record restricted to the one-drain-per-write case.
WRITE_RES_FIELDS = ("dcount", "dlog_key", "dlog_ver", "dlog_wts",
                    "dlog_rts", "dlog_gseq")


def write_rounds(kids, s1, s2, shard, rep, pending, maxif):
    """Split a write batch (op order) into conflict-free rounds for the
    batched write pass, simulating the bounded ring's drain schedule.

    Each op posts a pending line into the submitting replica's tier
    (footprint: its key + its ``(rep, s1)`` set) and, when the queue
    exceeds ``maxif``, drains the queue HEAD — which touches the drained
    entry's TSU shard, its ``(node, s2)`` shared set, and (for entries
    queued before this round) its key + ``(drep, s1)`` replica set.  A
    round must keep all of these disjoint, with two write-specific rules:

      * at most one TSU write per shard per round — a second allocation
        in one shard is coupled to the first through the victim choice
        and the allocation sequencer (``state.tsu_commit_write_batch``'s
        contract);
      * a drain of an entry PUSHED EARLIER IN THIS ROUND is exempt from
        the key/replica-set check: its footprint was already claimed by
        the push, and the pass applies every pending install before any
        drain install, so the drain re-probes the pending line exactly
        as the sequential scan would.

    ``pending`` is the node's queue at batch start, oldest first, as
    ``(kid, s1, s2, shard, rep)`` tuples; ``rep`` the submitting
    replica.  Returns index arrays into the batch; concatenated they are
    ``range(len(kids))`` — rounds never reorder ops."""
    q = collections.deque(pending)
    q_round = collections.deque(-1 for _ in pending)   # round each entry
    rounds: List[np.ndarray] = []                      # was pushed in
    cur: List[int] = []
    seen_k, seen_1, seen_2, seen_sh = set(), set(), set(), set()
    r = 0
    kids, s1, s2, shard = (np.asarray(kids).tolist(), np.asarray(s1).tolist(),
                           np.asarray(s2).tolist(),
                           np.asarray(shard).tolist())
    for i, (k, a, b, sh) in enumerate(zip(kids, s1, s2, shard)):
        q.append((k, a, b, sh, rep))
        q_round.append(r)
        drain = len(q) > maxif
        e = q[0] if drain else None

        def footprint():
            fk, f1, f2, fsh = {k}, {(rep, a)}, set(), set()
            if drain:
                fsh.add(e[3])
                f2.add(e[2])
                if q_round[0] != r:        # not a same-round push: check
                    fk.add(e[0])           # the drained key + replica set
                    f1.add((e[4], e[1]))
            return fk, f1, f2, fsh

        fk, f1, f2, fsh = footprint()
        if (fk & seen_k) or (f1 & seen_1) or (f2 & seen_2) \
                or (fsh & seen_sh):
            rounds.append(np.asarray(cur, np.int64))
            cur = []
            seen_k, seen_1, seen_2, seen_sh = set(), set(), set(), set()
            r += 1
            q_round[-1] = r                # this push belongs to the new
            fk, f1, f2, fsh = footprint()  # round; exemption recomputed
        cur.append(i)
        seen_k |= fk
        seen_1 |= f1
        seen_2 |= f2
        seen_sh |= fsh
        if drain:
            q.popleft()
            q_round.popleft()
    rounds.append(np.asarray(cur, np.int64))
    return rounds


def make_write_pass(W1: int, W2: int, KS: int, NN: int, NR: int, Q: int,
                    MAXIF: int):
    """Build the vectorized write pass for one fabric geometry (W1/W2 =
    tier trash-way indices, KS = TSU shard count, NN/NR = node/replica
    counts, Q = ring capacity, MAXIF = max in-flight writes).

    The returned function has the signature
    ``pass_(af, kids, s1, s2, shard, masks, rep, node, wl, rd, wr)
    -> (af, res)``: kids/s1/s2/shard are [M] int32 op arrays (padded),
    ``masks`` the [R, M] round matrix from ``write_rounds``, rep/node/wl
    scalars (one replica, one uniform write-lease override per
    ``write_batch`` call), and ``res`` the packed [6, M]
    ``WRITE_RES_FIELDS`` block.

    Each round reproduces the op-scan's write path over a whole
    conflict-free round at once:

      * the drain schedule in closed form — with round-start queue
        length L and push rank p = cumsum(active), op i drains iff
        ``L + p_i > MAXIF`` and pops relative ring index
        ``L + p_i - MAXIF - 1`` (the queue length invariantly re-caps at
        MAXIF after every op, so each push drains at most once);
      * an unwrapped staging buffer (MAXIF pre-round head entries + the
        round's pushes, ordered by queue position) resolves every
        drained entry without dynamic wraparound — including a drain of
        a push from this very round (MAXIF = 0 drains its own push);
      * the real ring is updated with a keep-last scatter: two pushes
        collide mod Q only when exactly Q pushes apart, and the earlier
        one is provably drained before the later lands (the queue never
        holds Q entries: MAXIF + 1 <= Q - 1);
      * clocks via running maxima (DESIGN.md §9c prefix-sum style): the
        TSU grant is clock-independent, so the node clock after drain i
        is ``max(cts0, cummax(mwts)_i)`` and each replica clock chains
        the same way over its own drains — closed forms of the
        sequential ``install``/``cts_after_write`` recurrences;
      * LRU ticks via the §9c prefix sums: a pending install at op i
        writes rank ``c[i, rep]`` minus its own drain's contribution,
        the drain install writes ``c[i, drep]``, with c the 2-D cumsum
        of per-replica tick increments.

    All rounds run inside ONE ``lax.scan``; on the sharded fabric the
    caller wraps the pass in ``_shard_exchange`` so the packed TSU
    buffer is assembled with ONE collective per batch.
    """
    i32 = jnp.int32
    NG, NRK = len(G_KEYS), len(R_KEYS)
    b2i = lambda b: b.astype(i32)
    NEG = jnp.int32(-2 ** 30)
    SB = MAXIF + 1                     # staging slots ahead of the pushes

    def gsum(**kw):
        out = jnp.zeros((NG,), i32)
        return out.at[jnp.array([GI[k] for k in kw], i32)].add(
            jnp.stack(list(kw.values())))

    def rsum(**kw):
        out = jnp.zeros((NRK,), i32)
        return out.at[jnp.array([RI[k] for k in kw], i32)].add(
            jnp.stack(list(kw.values())))

    def tier_install(tier, gseq_a, idx, st, key, wts, rts, ver, gs, lru_v,
                     th, way, active, trash):
        """Vectorized ``install_at``: in place on ``(th, way)``, else the
        victim way; LRU values are the caller's prefix-sum ranks.  The
        round contract guarantees all active ``(idx, st)`` sets are
        distinct, so the scatters commute with the sequential order."""
        vic = S.victim(tier.tag, tier.lru, idx, st)
        w0 = jnp.where(th, way, vic)
        evicted = active & ~th & (tier.tag[idx, st, w0] != S.INVALID)
        w = jnp.where(active, w0, trash)

        def pt(a, v):
            return a.at[idx, st, w].set(jnp.where(active, v, a[idx, st, w]))

        tier2 = tier._replace(tag=pt(tier.tag, key), wts=pt(tier.wts, wts),
                              rts=pt(tier.rts, rts), ver=pt(tier.ver, ver),
                              lru=pt(tier.lru, lru_v))
        return tier2, pt(gseq_a, gs), evicted

    def round_body(af, out, act, kids, s1, s2, shard, rep, node, wl, rd,
                   wr):
        M = kids.shape[0]
        iota = jnp.arange(M, dtype=i32)
        reps = jnp.full((M,), rep, i32)
        nodes = jnp.full((M,), node, i32)

        # ---- drain schedule in closed form (see docstring)
        p = jnp.cumsum(b2i(act))
        L = af.wq_len[node]
        H = af.wq_head[node]
        drain = act & (L + p > MAXIF)
        Pn = p[-1]
        D = jnp.sum(b2i(drain))
        rel = L + p - MAXIF - 1                 # drained queue position

        # ---- staging buffer: queue positions [0, MAXIF) are the
        # pre-round head entries (a static ring gather — garbage beyond
        # the live length L is never read: pre-round drains have
        # rel < L), positions [L, L + Pn) this round's pushes (the
        # scatter lands after the prefill, overwriting the garbage tail)
        push_v = {"key": kids, "rep": reps, "wl": jnp.full((M,), wl, i32),
                  "shard": shard, "set1": s1, "set2": s2}
        pre = (H + jnp.arange(SB - 1, dtype=i32)) % Q
        pidx = jnp.where(act, L + p - 1, SB + M - 1)      # trash slot
        gi = jnp.where(drain, rel, SB + M - 1)

        def staged(f):
            st_ = jnp.zeros((SB + M,), i32).at[:SB - 1].set(
                af.wq[f][node, pre])
            return st_.at[pidx].set(jnp.where(act, push_v[f], st_[pidx]))[gi]

        dkey = staged("key")
        drep = jnp.clip(staged("rep"), 0, NR - 1)
        dwl = staged("wl")
        dshard = staged("shard")
        ds1 = staged("set1")
        ds2 = staged("set2")

        # ---- real ring update: keep-last scatter for the pushes (two
        # pushes collide mod Q only Q apart; the earlier is already
        # drained), head/len advanced by the round totals
        keep = act & (p + Q > Pn)
        slot = (H + L + p - 1) % Q
        nrow = jnp.where(keep, node, NN)        # OOB row -> dropped
        wq2 = {f: a.at[nrow, slot].set(push_v[f], mode="drop")
               for f, a in af.wq.items()}

        # ---- ONE batched TSU write for the round's drains (state rules)
        dwl_eff = jnp.where(dwl >= 0, dwl, wr)
        (mwts, mrts, dver, gs, evict, ovf, tsu2, ver2, gseq2, seq2, nseq2,
         gnext2) = S.tsu_commit_write_batch(
            af.tsu, af.tsu_ver, af.tsu_gseq, af.tsu_seq, af.tsu_nseq,
            af.gseq_next, dshard, dkey, dwl_eff, rd, drain)

        # ---- clock chains: running maxima reproduce the sequential
        # install/cts_after_write recurrences (grants are clock-free)
        cts0n = af.sh.cts[node]
        run_mw = jax.lax.cummax(jnp.where(drain, mwts, NEG))
        nwA = jnp.maximum(cts0n, run_mw)
        nrA = jnp.maximum(nwA + 1, mrts)
        onehot_d = (jnp.arange(NR, dtype=i32)[:, None] == drep[None, :]) \
            & drain[None, :]
        runsA = jax.lax.cummax(jnp.where(onehot_d, nwA[None, :], NEG),
                               axis=1)
        cts0r = af.rp.cts
        nwB = jnp.maximum(cts0r[drep], runsA[drep, iota])
        nrB = jnp.maximum(nwB + 1, nrA)
        exclA = jnp.concatenate([jnp.full((NR, 1), NEG), runsA[:, :-1]],
                                axis=1)
        pend_cts = jnp.maximum(cts0r[rep], exclA[rep])

        # ---- LRU ticks: §9c prefix sums over per-replica increments
        # (each op bumps its submitter's tick for the pending line, then
        # its drain bumps the drained entry's replica + the node tier)
        inc = b2i(act)[None, :] * b2i(jnp.arange(NR, dtype=i32)[:, None]
                                      == rep) + b2i(onehot_d)
        c = jnp.cumsum(inc, axis=1)
        tick0 = af.rp_tick
        lru_pend = tick0[rep] + c[rep] - b2i(drain & (drep == rep))
        lru_drain = tick0[drep] + c[drep, iota]
        c2 = jnp.cumsum(b2i(drain))
        lru_sh = af.sh_tick[node] + c2

        # ---- pending installs (store-buffer lines: wts=rts=cts, ver=-1)
        # against the pre-round replica state, then the drain installs —
        # whose probes run AFTER the pending scatters so a drain of a
        # same-round push sees its pending line, exactly as the scan does
        negs = jnp.full((M,), -1, i32)
        thP, wayP = S.probe(af.rp.tag, reps, s1, kids)
        rpA, rpgA, evP = tier_install(
            af.rp, af.rp_gseq, reps, s1, kids, pend_cts, pend_cts, negs,
            negs, lru_pend, thP & act, wayP, act, W1)
        thA, wayA = S.probe(af.sh.tag, nodes, ds2, dkey)
        sh2, shg2, ev1 = tier_install(
            af.sh, af.sh_gseq, nodes, ds2, dkey, nwA, nrA, dver, gs,
            lru_sh, thA & drain, wayA, drain, W2)
        thB, wayB = S.probe(rpA.tag, drep, ds1, dkey)
        rp2, rpg2, ev2 = tier_install(
            rpA, rpgA, drep, ds1, dkey, nwB, nrB, dver, gs, lru_drain,
            thB & drain, wayB, drain, W1)

        # ---- counters: the scan's per-write gv/rv calls, summed
        n = lambda b: jnp.sum(b2i(b))
        cross = drain & (dshard != node % KS)
        b12, b2m, big = S.link_bytes(Pn, D, n(cross))
        g2 = af.g + gsum(
            writes=Pn, l1_to_l2=Pn, l2_to_mm=D, write_throughs=D,
            pcie_blocks=n(cross), tsu_evictions=n(evict),
            overflow_reinits=n(ovf),
            capacity_evictions=n(evP) + n(ev1) + n(ev2),
            bytes_l1_l2=b12, bytes_l2_mm=b2m, bytes_inter_gpu=big)
        r2 = af.r.at[rep].add(rsum(
            writes=Pn, l1_to_l2=Pn, capacity_evictions=n(evP)))
        r2 = r2.at[drep, RI["write_throughs"]].add(b2i(drain))
        r2 = r2.at[drep, RI["capacity_evictions"]].add(b2i(ev2))

        af = af._replace(
            rp=rp2._replace(cts=jnp.maximum(cts0r, runsA[:, -1])),
            rp_gseq=rpg2, rp_tick=tick0 + c[:, -1],
            sh=sh2._replace(cts=af.sh.cts.at[node].set(
                jnp.maximum(cts0n, run_mw[-1]))),
            sh_gseq=shg2, sh_tick=af.sh_tick.at[node].add(D),
            tsu=tsu2, tsu_ver=ver2, tsu_gseq=gseq2, tsu_seq=seq2,
            tsu_nseq=nseq2, gseq_next=gnext2,
            wq=wq2, wq_head=af.wq_head.at[node].set((H + D) % Q),
            wq_len=af.wq_len.at[node].add(Pn - D), g=g2, r=r2)

        vals = jnp.stack([
            b2i(drain), jnp.where(drain, dkey, -1),
            jnp.where(drain, dver, -1), jnp.where(drain, mwts, -1),
            jnp.where(drain, mrts, -1), jnp.where(drain, gs, -1),
        ])                                       # WRITE_RES_FIELDS order
        return af, jnp.where(act[None, :], vals, out)

    def pass_(af, kids, s1, s2, shard, masks, rep, node, wl, rd, wr):
        out0 = jnp.zeros((len(WRITE_RES_FIELDS), kids.shape[0]), i32)

        def step(carry, act):
            af, out = carry
            return round_body(af, out, act, kids, s1, s2, shard, rep,
                              node, wl, rd, wr), None

        (af, out), _ = jax.lax.scan(step, (af, out0), masks)
        return af, out

    return pass_


# -------------------------------------------------- collective accounting
def collective_counts(jaxpr) -> dict:
    """Walk a (closed) jaxpr and count collective primitives: ``total``
    occurrences and how many sit inside a scan/while body (``in_loop``).
    A collective inside a loop executes once PER ITERATION — the exact
    O(ops)-collectives failure mode the batched pipeline removes — so the
    parity suite pins ``in_loop == 0`` and ``total`` == the per-batch
    collective budget for ``pipeline="batched"``.  (The miss pass's round
    scan is collective-free: its one gather sits OUTSIDE the scan.)

    The walker itself now lives in ``repro.obs.xprof`` (the observability
    layer's static cost probe, which also reports per-primitive counts
    and compiled FLOPs/bytes); this wrapper keeps the parity suite's
    two-field view."""
    from repro.obs.xprof import jaxpr_collectives

    c = jaxpr_collectives(jaxpr)
    return {"total": c["total"], "in_loop": c["in_loop"]}
