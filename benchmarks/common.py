"""Shared benchmark helpers: timing, CSV rows, artifact caching, and the
batched ``sweep()`` entrypoint every figure script drives (DESIGN.md §5).

Artifacts are JSON files under ``benchmarks/artifacts/`` wrapped in an
envelope ``{"__meta__": {...}, "data": ...}``.  The meta block records a
hash of the emitting script (plus this harness), so committed artifacts
self-invalidate when the code that produced them changes — a stale artifact
can no longer mask a code change.  ``--force`` refreshes unconditionally.
"""
from __future__ import annotations

import hashlib
import inspect
import json
import pathlib
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
ART.mkdir(parents=True, exist_ok=True)

_rows: List[str] = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def rows() -> List[str]:
    return list(_rows)


def _fingerprint(fn: Callable, script: Optional[str] = None) -> str:
    """Hash of the emitting script (defaults to fn's source file), this
    harness, and the simulator core — the artifact's validity key.  An
    engine/kernel/trace change invalidates every cached figure, not just
    edits to the benchmark script itself."""
    paths = []
    src = script or inspect.getsourcefile(fn)
    if src:
        paths.append(pathlib.Path(src))
    paths.append(pathlib.Path(__file__))
    try:
        import repro.coherence.fabric
        import repro.core
        import repro.kernels
        import repro.launch.mesh
        import repro.obs
        import repro.sharding
        # obs is hashed too: the tracer/histogram layer shapes the
        # recorded rows (percentiles, phase breakdowns), so an obs change
        # must invalidate cached bench artifacts
        for pkg in (repro.core, repro.kernels, repro.coherence.fabric,
                    repro.obs):
            paths.extend(sorted(pathlib.Path(pkg.__file__).parent
                                .glob("*.py")))
        # the coherence package itself is a namespace package (no
        # __init__), so walk up from fabric/ for the serving adapters
        # (kv_lease/lease_sync) — a batched-contract change must
        # invalidate cached fabric rows too.  This also covers the new
        # state-layer module fabric/pipeline.py via the glob above.
        paths.extend(sorted(pathlib.Path(repro.coherence.fabric.__file__)
                            .parent.parent.glob("*.py")))
        # mesh-layout sources: a fabric/sharding rule change must
        # invalidate cached artifacts too
        paths.append(pathlib.Path(repro.sharding.__file__))
        paths.append(pathlib.Path(repro.launch.mesh.__file__))
        # the write-bench rows (batched_writes) depend on the transition
        # rules in core/state.py and the host queue in fabric/writeq.py;
        # both are already inside the package globs above, but pin the
        # two files explicitly so the cached rows keep self-invalidating
        # even if the glob set is ever narrowed
        paths.append(pathlib.Path(repro.core.__file__).parent / "state.py")
        paths.append(pathlib.Path(repro.coherence.fabric.__file__).parent
                     / "writeq.py")
    except ImportError:
        pass
    h = hashlib.sha256()
    for p in paths:
        try:
            h.update(p.read_bytes())
        except OSError:
            pass
    return h.hexdigest()[:16]


def cached(name: str, fn: Callable[[], Dict], force: bool = False,
           script: Optional[str] = None) -> Dict:
    """Run-once artifact cache keyed on the emitting script's content.

    The artifact is recomputed when (a) it doesn't exist, (b) ``force`` is
    set, or (c) the script that emitted it (or this harness) has changed
    since it was written — stale committed artifacts no longer mask code
    changes.  Pre-envelope artifacts (bare JSON) are treated as stale."""
    path = ART / f"{name}.json"
    fp = _fingerprint(fn, script)
    if path.exists() and not force:
        try:
            blob = json.loads(path.read_text())
        except json.JSONDecodeError:
            blob = None
        if (isinstance(blob, dict) and "__meta__" in blob
                and blob["__meta__"].get("script_sha") == fp):
            return blob["data"]
    out = fn()
    path.write_text(json.dumps(
        {"__meta__": {"script_sha": fp,
                      "script": pathlib.Path(
                          script or inspect.getsourcefile(fn) or "?").name},
         "data": out}, indent=1))
    return out


def timed(fn, *args) -> tuple:
    t0 = time.time()
    out = fn(*args)
    return out, (time.time() - t0) * 1e6


def sweep(configs: Sequence[Tuple[str, object]],
          named_traces: Dict[str, tuple], *,
          measure_sequential: bool = True) -> Dict:
    """The shared figure-engine entrypoint: run a (config x benchmark) grid
    through ``core.engine.sweep`` — ONE batched jit for the whole matrix —
    and optionally time the old per-cell sequential loop for comparison.

    configs: [(display_name, SystemConfig)]; named_traces: {bench: (ops
    [NC, T], addrs)}.  Returns a JSON-able dict: per-config cycles,
    counters (incl. L1<->L2 / L2<->MM transactions), and wall-clock of
    batched vs sequential driving.  Cold times include compilation — the
    realistic "run the figures from scratch" cost."""
    import jax

    from repro.core import engine, traces

    cnames = [n for n, _ in configs]
    cfgs = [c for _, c in configs]
    bnames = list(named_traces)
    ops_b, addrs_b = traces.pack_batch([named_traces[b] for b in bnames])

    t0 = time.time()
    res = engine.sweep(cfgs, ops_b, addrs_b)
    jax.block_until_ready(res)
    batched_cold = time.time() - t0
    t0 = time.time()
    res = engine.sweep(cfgs, ops_b, addrs_b)
    jax.block_until_ready(res)
    batched_steady = time.time() - t0

    out = {
        "configs": cnames,
        "benchmarks": bnames,
        "cycles": [[float(res["cycles"][ci, bi]) for bi in range(len(bnames))]
                   for ci in range(len(cnames))],
        "makespan_max": [[float(res["makespan_max"][ci, bi])
                          for bi in range(len(bnames))]
                         for ci in range(len(cnames))],
        "counters": {k: [[float(res["counters"][k][ci, bi])
                          for bi in range(len(bnames))]
                         for ci in range(len(cnames))]
                     for k in res["counters"]},
        "wall": {"batched_cold_s": batched_cold,
                 "batched_steady_s": batched_steady},
    }

    if measure_sequential:
        t0 = time.time()
        seq = [[float(engine.simulate(c, *named_traces[b])["cycles"])
                for b in bnames] for c in cfgs]
        sequential_cold = time.time() - t0
        t0 = time.time()   # second pass reuses the per-cell jits (steady)
        seq = [[float(engine.simulate(c, *named_traces[b])["cycles"])
                for b in bnames] for c in cfgs]
        sequential_steady = time.time() - t0
        out["sequential_cycles"] = seq
        out["wall"]["sequential_cold_s"] = sequential_cold
        out["wall"]["sequential_steady_s"] = sequential_steady
        out["wall"]["batched_speedup_cold"] = sequential_cold / batched_cold
        out["wall"]["batched_speedup_steady"] = \
            sequential_steady / max(batched_steady, 1e-9)
    return out
