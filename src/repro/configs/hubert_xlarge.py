"""hubert-xlarge [audio] — encoder-only (w2v2 arch); modality frontend is a
stub (input_specs supplies precomputed frame embeddings, d=512).
[arXiv:2106.07447] 48L d_model=1280 16H d_ff=5120 vocab=504."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, d_head=80,
    d_ff=5120, vocab=504, causal=False,
    frontend="audio", d_frontend=512,
)
