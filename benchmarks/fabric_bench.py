"""Coherence-fabric benchmark: hit-rate/traffic vs. leases + the
batched-vs-host throughput trajectory.

Drives the TSU service with three host-side workloads and reports the full
FabricStats block per scenario per lease setting — the production-path
counterpart of the simulator's Fig. 7/8 sweeps (same counter names, so rows
are directly comparable):

  shared_prefix  — multi-node serving: replicas re-read a hot set of prefix
                   blocks; a writer occasionally republishes (model refresh).
  local_sgd      — training: W workers read their param blocks each step and
                   write through once per wr_lease-step window, with a fence
                   at the window boundary (the all-reduce).
  mixed_churn    — 50/50 read-write over a key space larger than the caches:
                   worst case for lease reuse, stresses victim-way eviction.

plus the array-native headline (DESIGN.md §7):

  batched_serving — the steady-state serving hot path (every prefix under a
                    live lease) as batched reads: the host-object backend
                    (one Python call per key) vs the array backend (ONE
                    vectorized state.tier_probe per batch).  Both backends
                    are bit-identical (tests/test_fabric_parity.py); this
                    row is the wall-clock payoff.

  sharded_serving — the mesh-placed fabric (DESIGN.md §8) on a MISS-HEAVY
                    stream: the batched grant pipeline (ONE packed
                    collective per batch, DESIGN.md §9) vs the per-op
                    collective scan schedule on identical streams across
                    every visible device (8 under CI's forced host mesh),
                    with the Fig-10 traffic split.  BENCH_fabric.json's
                    ``_meta`` records shard count, device kind, git SHA and
                    jax version so the trajectory is comparable across PRs.

  scan_path       — us/op of the exact op-scan vs the batched pipeline on
                    identical miss-heavy read batches (ROADMAP scan-path
                    item), single device.

  batched_grants  — structural per-batch collective counts from the
                    compiled jaxpr: O(1) for the batched pipeline vs
                    O(batch) for the scan schedule (the acceptance pin,
                    as a recorded number).

  batched_writes  — republish STORMS through the batched write pass
                    (DESIGN.md §11): every batch re-publishes hot
                    prefixes via ``write_batch``, timed against the
                    per-op scan schedule on identical streams (stats
                    asserted equal, fences untimed), plus the write
                    pass's structural one-collective-per-storm count.

Results land in benchmarks/artifacts AND a root-level ``BENCH_fabric.json``
(the repo's perf trajectory file: batched vs host ops/sec + sweep wall).

    PYTHONPATH=src python benchmarks/fabric_bench.py [--ops 4000] [--json PATH]

Runs on CPU in a couple of minutes (jit compile included); emits JSON to
stdout, benchmarks/artifacts, and BENCH_fabric.json.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.coherence.fabric import (ArrayFabric, FabricConfig,  # noqa: E402
                                    HostFabric, ReplicaCache,
                                    ShardedArrayFabric, SharedCache,
                                    TSUFabric)
from repro.obs import LatencyHistogram  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402
from repro.runtime.loadgen import BoundedZipf  # noqa: E402

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
BENCH_PATH = pathlib.Path(__file__).resolve().parent.parent / "BENCH_fabric.json"

LEASE_GRID = [(2, 2), (8, 4), (32, 16)]


def build(rd, wr, *, n_nodes=2, replicas_per_node=2, n_shards=4,
          max_in_flight=8):
    fabric = TSUFabric(FabricConfig(n_shards=n_shards, rd_lease=rd,
                                    wr_lease=wr, max_in_flight=max_in_flight))
    nodes = [SharedCache(fabric, node_id=i) for i in range(n_nodes)]
    replicas = [ReplicaCache(nodes[i]) for i in range(n_nodes)
                for _ in range(replicas_per_node)]
    return fabric, nodes, replicas


def scenario_shared_prefix(rd, wr, ops):
    """Hot prefix blocks read by every replica; periodic republish."""
    fabric, nodes, replicas = build(rd, wr)
    rng = np.random.default_rng(0)
    hot = [f"prefix/{i}" for i in range(16)]
    # bounded Zipf (loadgen): numpy's rng.zipf is UNBOUNDED, and the old
    # ``rng.zipf(1.5) % len(hot)`` wrapped the infinite tail back onto
    # the hot set, silently flattening the skew this scenario exists to
    # exercise (ISSUE 9 satellite)
    zipf = BoundedZipf(len(hot), 1.5)
    writer = replicas[0]
    for k in hot:
        writer.put(k, f"{k}@0")
    for t in range(ops):
        r = replicas[int(rng.integers(len(replicas)))]
        k = hot[zipf.sample(rng)]
        r.get(k)
        if t % 200 == 199:                 # model refresh: republish one block
            writer.put(hot[int(rng.integers(len(hot)))], f"v@{t}")
        if t % 500 == 499:                 # periodic reader sync point
            fabric.barrier()
    return fabric


def scenario_local_sgd(rd, wr, ops):
    """Each worker reads its param blocks every step; write-through + fence
    once per wr_lease-step window (the paper's lease-synced local SGD)."""
    fabric, nodes, replicas = build(rd, wr)
    params = [f"param/{i}" for i in range(8)]
    for k in params:
        replicas[0].put(k, 0)
    fabric.barrier()
    steps = max(1, ops // (len(replicas) * len(params)))
    for step in range(steps):
        for w, r in enumerate(replicas):
            for k in params:
                r.get(k)
        if (step + 1) % wr == 0:           # window boundary: all-reduce
            for w, r in enumerate(replicas):
                for k in params:
                    r.put(k, step)
            fabric.barrier()
    return fabric


def scenario_mixed_churn(rd, wr, ops):
    """Uniform 50/50 read-write over a key space bigger than the caches."""
    fabric, nodes, replicas = build(rd, wr)
    rng = np.random.default_rng(1)
    keys = [f"blk/{i}" for i in range(512)]
    for k in keys[::8]:
        replicas[0].put(k, 0)
    for t in range(ops):
        r = replicas[int(rng.integers(len(replicas)))]
        k = keys[int(rng.integers(len(keys)))]
        if rng.random() < 0.5:
            r.get(k)
        else:
            r.put(k, t)
    fabric.barrier()
    return fabric


SCENARIOS = {
    "shared_prefix": scenario_shared_prefix,
    "local_sgd": scenario_local_sgd,
    "mixed_churn": scenario_mixed_churn,
}


# ------------------------------------------------- batched vs host serving
def scenario_batched_serving(ops: int = 16384, n_hot: int = 1024,
                             batch: int = 4096) -> dict:
    """Steady-state batched serving: identical op streams through both
    backends of the parity contract; reports ops/sec and the speedup.
    Each call pools several decode rounds over the hot set (continuous
    batching) — exactly what ``Server.serve`` does per call."""
    cfg = FabricConfig(n_shards=4, rd_lease=8, wr_lease=4,
                       replica_sets=512, replica_ways=8,
                       shared_sets=1024, shared_ways=8)
    hot = [f"prefix/{i}" for i in range(n_hot)]

    rounds = max(1, batch // n_hot)     # decode rounds pooled per call

    def warm(backend):
        backend.write_batch([(k, f"{k}@0") for k in hot], replica=0)
        backend.fence()
        backend.read_batch(hot, replica=1)            # fill replica 1's tier
        backend.read_batch(hot * rounds, replica=1)   # compile at bench shape

    host = HostFabric(cfg, n_nodes=2, replicas_per_node=2)
    arr = ArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    t0 = time.time()
    warm(arr)
    warm_s = time.time() - t0
    warm(host)
    n_batches = max(2, ops // batch)
    rng = np.random.default_rng(0)
    batches = [[hot[i] for _ in range(rounds)
                for i in rng.permutation(n_hot)][:batch]
               for _ in range(n_batches)]
    n = n_batches * batch
    t0 = time.time()
    for ks in batches:
        host.read_batch(ks, replica=1)
    host_s = time.time() - t0
    fb0 = arr.fast_read_batches
    arr_walls = []
    for ks in batches:
        t0 = time.time()
        arr.read_batch(ks, replica=1)
        arr_walls.append(time.time() - t0)
    arr_s = sum(arr_walls)
    _, batch_us = _batch_latency(arr_walls)
    batch_us["compile_us"] = round(warm_s * 1e6, 1)
    return {
        "ops": n, "batch": batch, "n_hot": n_hot,
        "host_ops_per_sec": round(n / host_s, 1),
        "array_ops_per_sec": round(n / arr_s, 1),
        "batched_speedup": round(host_s / arr_s, 2),
        "fast_batches": arr.fast_read_batches - fb0,
        "array_warm_s": round(warm_s, 2),
        "array_batch_us": batch_us,
        "obs_overhead": _obs_overhead(arr, batches[0],
                                      batch_us["p50_us"]),
    }


def _obs_overhead(arr, batch_keys, batch_p50_us) -> dict:
    """The <1% gate, measured (DESIGN.md §10): spans-per-batch on THIS
    path (counted with tracing on for one batch) x the measured cost of
    one DISABLED span = the tax tracing-off leaves on a serving batch.
    The A/B it replaces — timing an uninstrumented build — no longer
    exists; this decomposition is also immune to wall-clock noise."""
    tr = obs_trace.Tracer(enabled=True)
    old = obs_trace.set_tracer(tr)
    try:
        arr.read_batch(batch_keys, replica=1)
    finally:
        obs_trace.set_tracer(old)
    spans = len(tr.events)
    span_ns = obs_trace.disabled_span_cost_ns()
    overhead_us = spans * span_ns / 1e3
    return {
        "spans_per_batch": spans,
        "disabled_span_ns": round(span_ns, 1),
        "batch_p50_us": batch_p50_us,
        "overhead_pct": round(100.0 * overhead_us
                              / max(batch_p50_us, 1e-9), 4),
    }


def _batch_latency(walls) -> tuple:
    """Per-batch walls -> (median seconds, percentile row).  The row is
    the obs histogram's exact-percentile summary (p50/p95/p99 in us) —
    the single-median report kept hiding tail recompiles; now the tail
    is a first-class column."""
    h = LatencyHistogram()
    h.record_many(walls)
    s = h.summary()
    return s["p50_us"] / 1e6, {k: s[k] for k in
                               ("count", "p50_us", "p95_us", "p99_us",
                                "max_us")}


def _phase_breakdown(backend, batches, hot, n_traced=2) -> dict:
    """Re-drive ``n_traced`` batches with tracing ON (a scoped tracer, so
    the timed rows above stay untraced/unfenced) and aggregate the span
    taxonomy into us-per-batch per phase: where a miss-heavy serving
    batch actually spends its wall clock."""
    tr = obs_trace.Tracer(enabled=True)
    old = obs_trace.set_tracer(tr)
    try:
        _drive_miss_heavy(backend, batches[:n_traced], hot)
    finally:
        obs_trace.set_tracer(old)
    return {name: {"count": v["count"],
                   "us_per_batch": round(v["total_us"] / n_traced, 1)}
            for name, v in sorted(tr.phase_totals("fabric.").items())}


def _miss_heavy_batches(hot, batch, n_batches, seed=0):
    """Deduplicated (serving-style) batches over the hot set: each batch
    is a permutation slice, so the miss pass runs conflict-light rounds."""
    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_batches):
        perm = rng.permutation(len(hot))
        out.append([hot[i] for i in perm[:batch]])
    return out


def _drive_miss_heavy(backend, batches, hot, reader=1, writer=0,
                      republish=16):
    """The miss-heavy steady state: every read batch is preceded by a
    republish slice + fence, so the reader's leases are expired and the
    whole batch descends to the TSU (phase 2 of the batched read).
    Returns per-batch wall seconds — callers report the MEDIAN so a
    stray mid-loop XLA recompile (pow2 shape churn) or scheduler hiccup
    cannot masquerade as steady-state cost."""
    walls = []
    for t, ks in enumerate(batches):
        t0 = time.time()
        sl = [hot[(t * republish + j) % len(hot)] for j in range(republish)]
        backend.write_batch([(k, f"v@{t}") for k in sl], replica=writer)
        backend.fence()
        backend.read_batch(ks, replica=reader)
        walls.append(time.time() - t0)
    return walls


def _assert_steady(row: dict, what: str) -> None:
    """Steady-state hygiene, asserted in the bench itself (ISSUE 9
    satellite): once every shape bucket is warmed before timing, the
    timed tail can only be scheduler noise — a p99 at 10x the p50 means
    a compile/transfer wall leaked back into the timed section and the
    percentile columns are lying again."""
    assert row["p99_us"] < 10 * row["p50_us"], (
        f"{what}: p99 {row['p99_us']}us >= 10x p50 {row['p50_us']}us — "
        f"a compile wall polluted the timed steady state ({row})")


def _timed_drive(backend, batches, hot):
    """Split a miss-heavy drive into the untimed warm section and the
    timed steady state (ISSUE 8 bench hygiene, tightened by ISSUE 9):
    the warm pass drives the ENTIRE batch list once untimed, so EVERY
    pow2 shape bucket the timed loop touches (miss-subset lanes M, round
    masks R per conflict pattern, the write-slice storm shape and the
    fence drain) is compiled before timing starts — warming only the
    first two batches left later batches free to land in a fresh R/M
    bucket and swallow a compile wall mid-loop (the p95/p99 ~100x p50
    rows in the old trajectory).  Re-driving the same list reproduces
    the shapes exactly (the republish slices are enumerate-indexed), the
    warm wall lands in ``compile_us``, and the timed tail is asserted
    clean."""
    t0 = time.time()
    _drive_miss_heavy(backend, batches, hot)     # full warm: every bucket
    compile_us = round((time.time() - t0) * 1e6, 1)
    p50_s, row = _batch_latency(_drive_miss_heavy(backend, batches, hot))
    row["compile_us"] = compile_us
    _assert_steady(row, "timed miss-heavy drive")
    return p50_s, row


def scenario_scan_path(ops: int = 8192, n_hot: int = 512,
                       batch: int = 256) -> dict:
    """The scan-path microbench (ROADMAP item): us/op of the exact op-scan
    (``pipeline="scan"`` serves every miss one scan step at a time)
    against the batched grant pipeline (``pipeline="batched"`` serves the
    whole miss subset in a few vectorized rounds) on IDENTICAL miss-heavy
    read streams.  Stats equality is asserted — the two pipelines are the
    same protocol, only the execution schedule differs."""
    cfg = FabricConfig(n_shards=4, rd_lease=8, wr_lease=4,
                       replica_sets=1024, replica_ways=8,
                       shared_sets=2048, shared_ways=8)
    hot = [f"prefix/{i}" for i in range(n_hot)]
    n_batches = max(6, ops // batch)     # >= 6 timed batches (full warm)
    batches = _miss_heavy_batches(hot, batch, n_batches)

    def bench(pipe):
        fab = ArrayFabric(cfg, n_nodes=2, replicas_per_node=2,
                          pipeline=pipe)
        fab.write_batch([(k, f"{k}@0") for k in hot], replica=0)
        fab.fence()
        fab.read_batch(hot, replica=1)               # fill + compile
        # full warm pass at the timed sizes (every shape bucket), then
        # the timed steady state; warm wall lands in compile_us
        p50_s, row = _timed_drive(fab, batches, hot)
        return fab, p50_s, row

    scan_fab, scan_s, scan_row = bench("scan")
    batched_fab, batched_s, batched_row = bench("batched")
    assert scan_fab.stats() == batched_fab.stats(), \
        "batched pipeline diverged from the op-scan"
    st = scan_fab.stats()
    miss_rate = (st["l1_to_l2"] - st["writes"]) / max(st["reads"], 1)
    return {
        "ops": n_batches * batch, "batch": batch, "n_hot": n_hot,
        "miss_rate": round(miss_rate, 3),
        "scan_us_per_op": round(scan_s / batch * 1e6, 2),
        "batched_us_per_op": round(batched_s / batch * 1e6, 2),
        "batched_speedup": round(scan_s / batched_s, 2),
        "scan_batch_us": scan_row,
        "batched_batch_us": batched_row,
    }


def scenario_batched_grants(n_shards: int = 8, batch: int = 512,
                            with_cost: bool = True) -> dict:
    """Structural collective accounting for the sharded fabric (the
    acceptance pin, measured): how many cross-shard collectives one
    batch of ``batch`` ops issues under each pipeline, counted in the
    compiled jaxpr (a collective inside the scan body executes once per
    op).  The batched grant pipeline is O(1) per batch; the per-op scan
    schedule is O(batch).  ``with_cost`` adds XLA's compiled cost
    analysis (FLOPs / bytes accessed per batch, ``obs.xprof.cost_probe``)
    so a perf regression can be split into "the program got bigger" vs
    "the program got slower"; mini runs skip it (it pays a full XLA
    compile per pipeline)."""
    import jax
    import jax.numpy as jnp

    from repro.obs.xprof import cost_probe, jaxpr_collectives

    cfg = FabricConfig(n_shards=n_shards, rd_lease=8, wr_lease=4)
    xs = {k: jnp.zeros((batch,), jnp.int32) for k in
          ("kind", "rep", "node", "key", "set1", "set2", "shard", "wl")}
    out = {"batch": batch, "n_shards": n_shards}
    for pipe in ("batched", "scan"):
        fab = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2,
                                 pipeline=pipe)
        af = fab._af
        if pipe == "batched":
            # the dev0 pass engine (DESIGN.md §9/§12a): the batch's ONE
            # collective lives in the dedicated grant-exchange program;
            # the miss pass itself is collective-free
            progs = [
                ("gather", fab._gather_run,
                 (af.tsu, af.tsu_ver, af.tsu_gseq, af.tsu_seq,
                  af.tsu_nseq)),
                ("miss_pass", fab._miss_run,
                 (af, jnp.zeros((4, batch), jnp.int32),
                  jnp.zeros((4, batch), bool), jnp.int32(1), jnp.int32(0),
                  jnp.int32(8), jnp.int32(4))),
            ]
        else:
            progs = [("scan", fab._run,
                      (af, xs, jnp.int32(8), jnp.int32(4)))]
        total = in_loop = 0
        flops = bytes_acc = 0 if with_cost else None
        parts = {}
        for pname, prog, pargs in progs:
            if with_cost:
                probe = cost_probe(prog, *pargs)
                c = probe["collectives"]
                flops += probe["flops"] or 0
                bytes_acc += probe["bytes_accessed"] or 0
            else:                   # mini/CI: skip the XLA compile
                c = jaxpr_collectives(jax.make_jaxpr(prog)(*pargs))
            total += c["total"]
            in_loop += c["in_loop"]
            parts[pname] = dict(c)
        out[pipe] = {
            "collectives_traced": total,
            "in_scan_body": in_loop,
            "collectives_per_batch": total - in_loop + in_loop * batch,
            "programs": parts,
            "flops": flops,
            "bytes_accessed": bytes_acc,
        }
        out["devices"] = fab.n_shard_devices
    return out


def scenario_batched_writes(ops: int = 8192, n_hot: int = 512,
                            batch: int = 512) -> dict:
    """Republish storms through the batched write pass vs the per-op
    scan schedule (DESIGN.md §11): every storm re-publishes ``batch``
    hot prefixes via one ``write_batch`` call on IDENTICAL streams.
    Only the ``write_batch`` call is timed — the fence that drains the
    posted tail runs untimed between storms — and the two pipelines'
    stats blocks are asserted equal afterwards (same protocol, only the
    execution schedule differs).  The wide geometry (64 shards, roomy
    tiers) keeps the storms conflict-light, so the batched pass runs
    genuinely vectorized rounds; ``write_pass_collectives`` records the
    structural pin that one sharded storm issues exactly ONE packed
    collective (the scan body keeps one per op)."""
    import jax
    import jax.numpy as jnp

    from repro.obs.xprof import jaxpr_collectives

    cfg = FabricConfig(n_shards=64, rd_lease=8, wr_lease=4,
                       max_in_flight=8, replica_sets=2048, replica_ways=8,
                       shared_sets=4096, shared_ways=8)
    hot = [f"prefix/{i}" for i in range(n_hot)]
    n_batches = max(6, ops // batch)     # >= 4 timed storms (2 warm)
    rng = np.random.default_rng(3)
    storms = [[(hot[i], f"v@{t}.{i}")
               for i in rng.permutation(n_hot)[:batch]]
              for t in range(n_batches)]

    def bench(pipe):
        fab = ArrayFabric(cfg, n_nodes=2, replicas_per_node=2,
                          pipeline=pipe)
        t0 = time.time()
        for items in storms:            # full warm: every storm's shape
            fab.write_batch(items, replica=0)
            fab.fence()
        compile_us = round((time.time() - t0) * 1e6, 1)
        walls = []
        for items in storms:
            t0 = time.time()
            fab.write_batch(items, replica=0)
            walls.append(time.time() - t0)
            fab.fence()                 # untimed drain between storms
        p50_s, row = _batch_latency(walls)
        row["compile_us"] = compile_us
        _assert_steady(row, f"batched_writes[{pipe}]")
        return fab, p50_s, row

    scan_fab, scan_s, scan_row = bench("scan")
    bat_fab, bat_s, bat_row = bench("batched")
    assert scan_fab.stats() == bat_fab.stats(), \
        "batched write pass diverged from the op-scan"

    # structural collective accounting for one sharded publish storm:
    # the dev0 pass engine's single collective is the grant-exchange
    # program; the write pass itself is collective-free
    sh = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2,
                            pipeline="batched")
    af = sh._af
    s0 = jnp.int32(0)
    cg = jaxpr_collectives(jax.make_jaxpr(sh._gather_run)(
        af.tsu, af.tsu_ver, af.tsu_gseq, af.tsu_seq, af.tsu_nseq))
    cw = jaxpr_collectives(jax.make_jaxpr(sh._write_run)(
        af, jnp.zeros((4, batch), jnp.int32),
        jnp.zeros((7, batch), jnp.int32), jnp.zeros((8, batch), bool),
        s0, s0, jnp.int32(-1), jnp.int32(cfg.rd_lease),
        jnp.int32(cfg.wr_lease)))
    sc = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2,
                            pipeline="scan")
    xs = {k: jnp.zeros((batch,), jnp.int32) for k in
          ("kind", "rep", "node", "key", "set1", "set2", "shard", "wl")}
    cs = jaxpr_collectives(jax.make_jaxpr(sc._run)(
        sc._af, xs, jnp.int32(cfg.rd_lease), jnp.int32(cfg.wr_lease)))
    speedup = round(scan_s / bat_s, 2)
    return {
        "ops": (n_batches - 2) * batch, "batch": batch, "n_hot": n_hot,
        "n_shards": cfg.n_shards,
        "scan_us_per_op": round(scan_s / batch * 1e6, 2),
        "batched_us_per_op": round(bat_s / batch * 1e6, 2),
        "batched_speedup": speedup,
        "bar_2x_met": speedup >= 2.0,
        "scan_batch_us": scan_row,
        "batched_batch_us": bat_row,
        "write_pass_collectives": {
            "batched_per_storm": (cg["total"] + cw["total"] - cw["in_loop"]
                                  + cw["in_loop"] * batch),
            "scan_per_storm": (cs["total"] - cs["in_loop"]
                               + cs["in_loop"] * batch),
        },
    }


def scenario_sharded_serving(ops: int = 8192, n_hot: int = 256,
                             batch: int = 1024, n_shards: int = 8) -> dict:
    """The mesh-placed fabric on a MISS-HEAVY serving stream (every read
    batch preceded by a republish + fence, so the whole batch descends to
    the sharded TSU): the batched grant pipeline (ONE packed collective
    per batch) against the ``pipeline="scan"`` per-op collective schedule
    on IDENTICAL streams, with the 1-device ``ArrayFabric`` as the
    bit-identity reference.  ``batched_over_scan`` is the acceptance
    headline — what batching the cross-shard grant exchange buys on
    however many devices this process sees (8 under CI's forced host
    mesh) — plus the Fig-10 traffic split the sharded run measured."""
    import jax

    cfg = FabricConfig(n_shards=n_shards, rd_lease=8, wr_lease=4,
                       replica_sets=1024, replica_ways=8,
                       shared_sets=2048, shared_ways=8)
    hot = [f"prefix/{i}" for i in range(n_hot)]
    # floor of 6 timed batches: percentile rows need a real sample count
    # even at mini sizes, not a 2-batch pseudo-median
    n_batches = max(6, ops // batch)
    batches = _miss_heavy_batches(hot, min(batch, n_hot), n_batches)

    def drive(backend):
        backend.write_batch([(k, f"{k}@0") for k in hot], replica=0)
        backend.fence()
        backend.read_batch(hot, replica=1)           # fill replica tier
        # full warm pass over every batch (every pow2 bucket compiled
        # before timing); cold wall goes to compile_us, the p50 keys the
        # speedup ratios, and the timed tail is asserted clean
        return _timed_drive(backend, batches, hot)

    single = ArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    batched = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2,
                                 pipeline="batched")
    scan = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2,
                              pipeline="scan")
    single_s, single_row = drive(single)
    batched_s, batched_row = drive(batched)
    scan_s, scan_row = drive(scan)
    assert single.stats() == batched.stats() == scan.stats(), \
        "sharded serving diverged across pipelines"
    st = batched.stats()
    b = min(batch, n_hot)
    # where a batched miss-heavy batch spends its wall (traced re-drive,
    # scoped tracer: the timed rows above ran untraced and unfenced)
    phases = _phase_breakdown(batched, batches[2:4], hot)
    return {
        "ops": n_batches * b, "batch": b, "n_hot": n_hot,
        "n_shards": n_shards,
        "shard_devices": batched.n_shard_devices,
        "single_ops_per_sec": round(b / single_s, 1),
        "sharded_ops_per_sec": round(b / batched_s, 1),
        "sharded_scan_ops_per_sec": round(b / scan_s, 1),
        "batched_over_scan": round(scan_s / batched_s, 3),
        "sharded_over_single": round(single_s / batched_s, 3),
        "batch_us": {"single": single_row, "batched": batched_row,
                     "scan": scan_row},
        "phases_us": phases,
        "bytes_inter_gpu": st["bytes_inter_gpu"],
        "bytes_l2_mm": st["bytes_l2_mm"],
        "bytes_l1_l2": st["bytes_l1_l2"],
        "inval_msgs": st["inval_msgs"],       # 0 by construction (Fig 10)
    }


def summarize(stats):
    d = stats.to_dict()
    lookups = d["l1_hits"] + d["l1_to_l2"]
    d["hit_rate_l1"] = round(d["l1_hits"] / max(lookups, 1), 4)
    d["mm_traffic_per_op"] = round(
        d["l2_to_mm"] / max(d["reads"] + d["writes"], 1), 4)
    return d


def _bench_meta(sharded: dict) -> dict:
    """Environment fingerprint for the perf trajectory: rows are only
    comparable across PRs when shard/device/jax provenance is recorded."""
    import subprocess

    import jax

    try:
        sha = subprocess.run(["git", "rev-parse", "--short", "HEAD"],
                             capture_output=True, text=True,
                             cwd=pathlib.Path(__file__).parent,
                             timeout=10).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        sha = None
    return {
        "generated_by": "benchmarks/fabric_bench.py",
        "git_sha": sha,
        "jax_version": jax.__version__,
        "device_kind": jax.devices()[0].platform,
        "n_devices": len(jax.devices()),
        "fabric_shards": sharded.get("n_shards"),
        "fabric_shard_devices": sharded.get("shard_devices"),
    }


def write_bench_json(sweep_wall_s: float, serving: dict, sharded: dict,
                     scan_path: dict = None, grants: dict = None,
                     writes: dict = None) -> None:
    """Root-level perf-trajectory artifact (ISSUE 3 satellite): the
    batched-vs-host ops/sec headline, the sharded-serving row (ISSUE 4),
    the scan-vs-batched-pipeline row + per-batch collective counts
    (ISSUE 5), the republish-storm write-path row (ISSUE 7), and the
    lease-sweep wall-clock."""
    blob = {
        "batched_serving": serving,
        "sharded_serving": sharded,
        "lease_sweep": {"wall_s": round(sweep_wall_s, 2),
                        "scenarios": list(SCENARIOS),
                        "lease_grid": LEASE_GRID},
        "_meta": _bench_meta(sharded),
    }
    if scan_path is not None:
        blob["scan_path"] = scan_path
    if grants is not None:
        blob["batched_grants"] = grants
    if writes is not None:
        blob["batched_writes"] = writes
    BENCH_PATH.write_text(json.dumps(blob, indent=1))
    print(f"wrote {BENCH_PATH}", file=sys.stderr)


def run(force: bool = False, mini: bool = False) -> None:
    """Harness entry point (benchmarks.run): cached sweep + CSV rows +
    the root-level BENCH_fabric.json trajectory file."""
    from benchmarks import common

    n_ops = 500 if mini else 4000

    def compute():
        out = {}
        t_sweep = time.time()
        for name, fn in SCENARIOS.items():
            out[name] = {}
            for rd, wr in LEASE_GRID:
                t0 = time.time()
                fabric = fn(rd, wr, n_ops)
                row = summarize(fabric.stats)
                row["wall_us"] = (time.time() - t0) * 1e6
                out[name][f"rd{rd}_wr{wr}"] = row
        out["_sweep_wall_s"] = time.time() - t_sweep
        out["_batched_serving"] = scenario_batched_serving(
            ops=2048 if mini else 16384)
        out["_sharded_serving"] = scenario_sharded_serving(
            ops=2048 if mini else 8192, n_hot=128 if mini else 256,
            batch=512 if mini else 1024)
        out["_scan_path"] = scenario_scan_path(
            ops=2048 if mini else 8192, n_hot=256 if mini else 512,
            batch=128 if mini else 256)
        out["_batched_grants"] = scenario_batched_grants(
            batch=128 if mini else 512)
        out["_batched_writes"] = scenario_batched_writes(
            ops=2048 if mini else 8192, n_hot=256 if mini else 512,
            batch=128 if mini else 512)
        return out

    # distinct cache names: mini and full runs must never serve each
    # other's artifact (op counts aren't part of the source fingerprint)
    out = common.cached("fabric_bench_suite_mini" if mini
                        else "fabric_bench_suite", compute, force=force)
    for name, grid in out.items():
        if name.startswith("_"):
            continue
        for lease, row in grid.items():
            common.emit(f"fabric/{name}/{lease}", row.get("wall_us", 0.0),
                        f"l1_hit={row['hit_rate_l1']};"
                        f"mm_per_op={row['mm_traffic_per_op']};"
                        f"inval={row['inval_msgs']}")
    srv = out["_batched_serving"]
    common.emit("fabric/batched_serving", 1e6 / srv["array_ops_per_sec"],
                f"speedup={srv['batched_speedup']}x;"
                f"host_ops={srv['host_ops_per_sec']};"
                f"array_ops={srv['array_ops_per_sec']}")
    shd = out["_sharded_serving"]
    common.emit("fabric/sharded_serving", 1e6 / shd["sharded_ops_per_sec"],
                f"devices={shd['shard_devices']};"
                f"shards={shd['n_shards']};"
                f"sharded_over_single={shd['sharded_over_single']}x;"
                f"batched_over_scan={shd['batched_over_scan']}x;"
                f"inter_gpu_bytes={shd['bytes_inter_gpu']}")
    scp = out["_scan_path"]
    common.emit("fabric/scan_path", scp["scan_us_per_op"],
                f"batched_us={scp['batched_us_per_op']};"
                f"speedup={scp['batched_speedup']}x;"
                f"miss_rate={scp['miss_rate']}")
    grt = out["_batched_grants"]
    common.emit("fabric/batched_grants", 0.0,
                f"batched_per_batch="
                f"{grt['batched']['collectives_per_batch']};"
                f"scan_per_batch={grt['scan']['collectives_per_batch']}")
    wrt = out["_batched_writes"]
    common.emit("fabric/batched_writes", wrt["batched_us_per_op"],
                f"scan_us={wrt['scan_us_per_op']};"
                f"speedup={wrt['batched_speedup']}x;"
                f"write_pass_collectives="
                f"{wrt['write_pass_collectives']['batched_per_storm']}")
    write_bench_json(out["_sweep_wall_s"], srv, shd, scp, grt, wrt)


def merge_sharded_row(ops: int) -> None:
    """Run ONLY the sharded_serving scenario and merge its row into an
    existing BENCH_fabric.json.  CI uses this under the forced 8-device
    mesh: the batched_serving trajectory row must come from an UNFORCED
    run (splitting the CPU into 8 host devices would skew it and break
    cross-PR comparability), while the sharded row wants the real mesh."""
    shd = scenario_sharded_serving(ops=max(1024, min(ops, 8192)),
                                   n_hot=128, batch=512)
    try:
        blob = json.loads(BENCH_PATH.read_text())
    except (OSError, json.JSONDecodeError):
        blob = {}
    blob["sharded_serving"] = shd
    meta = blob.setdefault("_meta", _bench_meta(shd))
    meta["fabric_shards"] = shd["n_shards"]
    meta["fabric_shard_devices"] = shd["shard_devices"]
    BENCH_PATH.write_text(json.dumps(blob, indent=1))
    print(f"sharded_serving {shd['sharded_ops_per_sec']:,.0f} ops/s on "
          f"{shd['shard_devices']} device(s) "
          f"(sharded_over_single {shd['sharded_over_single']}x, "
          f"batched_over_scan {shd['batched_over_scan']}x); "
          f"merged into {BENCH_PATH}", flush=True)


def write_trace(path: pathlib.Path, n_hot: int = 128,
                batch: int = 64) -> None:
    """Trace a mini miss-heavy serving run on the default fabric (the
    mesh-placed one when >1 device is visible) and export Chrome-trace
    JSON — open it in chrome://tracing or https://ui.perfetto.dev.  The
    first traced batch is deliberately cold (compile visible as a long
    ``fabric.scan``); the rest show the steady state.  CI uploads this
    for every run under its forced 8-device mesh."""
    from repro.coherence.fabric import default_fabric

    cfg = FabricConfig(n_shards=8, rd_lease=8, wr_lease=4,
                       replica_sets=512, replica_ways=8,
                       shared_sets=1024, shared_ways=8)
    fab = default_fabric(cfg, n_nodes=2, replicas_per_node=2)
    hot = [f"prefix/{i}" for i in range(n_hot)]
    batches = _miss_heavy_batches(hot, batch, 4)
    tr = obs_trace.Tracer(enabled=True)
    old = obs_trace.set_tracer(tr)
    try:
        with tr.span("serve.warm", cat="serve"):
            fab.write_batch([(k, f"{k}@0") for k in hot], replica=0)
            fab.fence()
            fab.read_batch(hot, replica=1)
        for ks in batches:
            with tr.span("serve.batch", cat="serve", n_keys=len(ks)):
                _drive_miss_heavy(fab, [ks], hot)
    finally:
        obs_trace.set_tracer(old)
    tr.export(path)
    totals = tr.phase_totals("fabric.")
    print(f"wrote {path} ({len(tr.events)} events; phases: "
          f"{', '.join(sorted(totals))})", flush=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ops", type=int, default=4000,
                    help="approximate client ops per scenario")
    ap.add_argument("--json", type=pathlib.Path,
                    default=ART / "fabric_bench.json")
    ap.add_argument("--skip-batched", action="store_true",
                    help="lease sweep only (no jit compile; fast smoke)")
    ap.add_argument("--sharded-only", action="store_true",
                    help="run only sharded_serving and merge the row into "
                         "BENCH_fabric.json (CI's forced-mesh step)")
    ap.add_argument("--trace-json", type=pathlib.Path, default=None,
                    help="trace a mini serving run and write Chrome-trace "
                         "JSON to PATH, then exit (CI's trace artifact)")
    args = ap.parse_args()

    if args.trace_json is not None:
        write_trace(args.trace_json)
        return
    if args.sharded_only:
        merge_sharded_row(args.ops)
        return

    t0 = time.time()
    out = {}
    for name, fn in SCENARIOS.items():
        out[name] = {}
        for rd, wr in LEASE_GRID:
            fabric = fn(rd, wr, args.ops)
            row = summarize(fabric.stats)
            out[name][f"rd{rd}_wr{wr}"] = row
            print(f"{name:14s} rd={rd:3d} wr={wr:3d} "
                  f"l1_hit={row['hit_rate_l1']:.3f} "
                  f"mm/op={row['mm_traffic_per_op']:.3f} "
                  f"inval={row['inval_msgs']} "
                  f"self_inval={row['self_invalidations']}", flush=True)
    sweep_wall = time.time() - t0
    if not args.skip_batched:
        srv = scenario_batched_serving(ops=max(2048, min(args.ops * 4, 16384)))
        out["batched_serving"] = srv
        print(f"batched_serving host={srv['host_ops_per_sec']:,.0f} ops/s "
              f"array={srv['array_ops_per_sec']:,.0f} ops/s "
              f"speedup={srv['batched_speedup']}x", flush=True)
        shd = scenario_sharded_serving(ops=max(2048, min(args.ops * 2, 8192)))
        out["sharded_serving"] = shd
        print(f"sharded_serving {shd['sharded_ops_per_sec']:,.0f} ops/s on "
              f"{shd['shard_devices']} device(s) "
              f"(sharded_over_single {shd['sharded_over_single']}x; "
              f"batched_over_scan {shd['batched_over_scan']}x; "
              f"inter_gpu_bytes={shd['bytes_inter_gpu']})", flush=True)
        scp = scenario_scan_path(ops=max(2048, min(args.ops * 2, 8192)))
        out["scan_path"] = scp
        print(f"scan_path scan={scp['scan_us_per_op']}us/op "
              f"batched={scp['batched_us_per_op']}us/op "
              f"({scp['batched_speedup']}x, miss_rate={scp['miss_rate']})",
              flush=True)
        grt = scenario_batched_grants()
        out["batched_grants"] = grt
        print(f"batched_grants per-batch collectives: "
              f"batched={grt['batched']['collectives_per_batch']} "
              f"scan={grt['scan']['collectives_per_batch']}", flush=True)
        wrt = scenario_batched_writes(ops=max(2048, min(args.ops * 2, 8192)))
        out["batched_writes"] = wrt
        print(f"batched_writes scan={wrt['scan_us_per_op']}us/op "
              f"batched={wrt['batched_us_per_op']}us/op "
              f"({wrt['batched_speedup']}x; one-collective storm="
              f"{wrt['write_pass_collectives']['batched_per_storm']})",
              flush=True)
        write_bench_json(sweep_wall, srv, shd, scp, grt, wrt)
    out["_meta"] = {"ops": args.ops, "lease_grid": LEASE_GRID,
                    "wall_s": round(time.time() - t0, 2)}
    args.json.parent.mkdir(parents=True, exist_ok=True)
    args.json.write_text(json.dumps(out, indent=1))
    print(json.dumps(out["_meta"]))
    print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
