"""Observability layer acceptance (DESIGN.md §10).

Four contracts:

  * histogram percentiles are numpy-exact while samples are retained and
    a sane bucket interpolation past the cap;
  * the registry's snapshot/delta windows tile FabricStats counters
    without gaps or double counting;
  * a REAL traced fabric batch exports schema-valid Chrome-trace JSON
    whose spans form a well-nested forest (strict stack discipline);
  * the <1% gate: with tracing disabled (the default), the span
    instrumentation left on the batched serving hot path costs under 1%
    of a serving batch — the paper's own overhead bar (§6.2) applied to
    our own telemetry.
"""
import json

import numpy as np
import pytest

from repro.coherence.fabric import ArrayFabric, FabricConfig
from repro.obs import LatencyHistogram, MetricsRegistry
from repro.obs import trace as obs_trace
from repro.obs.xprof import cost_probe, jaxpr_collectives


# ------------------------------------------------------------- histograms
def test_percentiles_match_numpy_exactly():
    rng = np.random.default_rng(0)
    samples = rng.lognormal(mean=-7.0, sigma=2.0, size=4096)  # ~µs..s
    h = LatencyHistogram()
    h.record_many(samples)
    assert h.exact
    for p in (0, 10, 50, 90, 95, 99, 99.9, 100):
        np.testing.assert_allclose(h.percentile(p),
                                   np.percentile(samples, p),
                                   rtol=0, atol=0, err_msg=f"p{p}")
    s = h.summary()
    assert s["count"] == len(samples) and s["exact"]
    np.testing.assert_allclose(s["p99_us"],
                               round(np.percentile(samples, 99) * 1e6, 2))


def test_percentiles_degrade_to_bucket_interpolation_past_cap():
    rng = np.random.default_rng(1)
    samples = rng.lognormal(mean=-9.0, sigma=1.0, size=512)
    h = LatencyHistogram(sample_cap=64)
    h.record_many(samples)
    assert not h.exact and not h.summary()["exact"]
    exact = np.percentile(samples, 95)
    est = h.percentile(95)
    # log-bucket estimate lands within one growth factor of the truth
    assert exact / 2.0 <= est <= exact * 2.0
    rows = h.buckets()
    assert rows[-1] == (float("inf"), len(samples))
    cum = [c for _, c in rows]
    assert cum == sorted(cum)                      # cumulative, monotone


def test_histogram_validation_and_merge():
    h = LatencyHistogram()
    with pytest.raises(ValueError):
        h.record(-1e-3)
    a = LatencyHistogram().record_many([1e-3, 2e-3])
    b = LatencyHistogram().record_many([4e-3])
    a.merge(b)
    assert a.count == 3 and a.max_s == 4e-3
    with pytest.raises(ValueError):
        a.merge(LatencyHistogram(base=1e-3))


# --------------------------------------------------------------- registry
def test_registry_deltas_tile_the_counter_timeline():
    reg = MetricsRegistry()
    key = ("fabric", "shared_prefix")
    reg.snapshot(key, {"reads": 10, "writes": 2})
    d1 = reg.delta(key, {"reads": 25, "writes": 2})
    assert d1 == {"reads": 15, "writes": 0}
    d2 = reg.delta(key, {"reads": 30, "writes": 7})   # advanced: no overlap
    assert d2 == {"reads": 5, "writes": 5}
    # advance=False peeks without moving the window
    d3 = reg.delta(key, {"reads": 31, "writes": 7}, advance=False)
    d4 = reg.delta(key, {"reads": 31, "writes": 7})
    assert d3 == d4 == {"reads": 1, "writes": 0}
    # a key with no snapshot diffs against zero
    assert reg.delta(("other",), {"reads": 3}) == {"reads": 3}


def test_registry_accepts_fabric_backends_and_summarizes():
    fab = ArrayFabric(FabricConfig(n_shards=2, rd_lease=4, wr_lease=2))
    reg = MetricsRegistry()
    key = ("array", "smoke")
    reg.snapshot(key, fab)                         # .stats() surface
    fab.write("k", "v")
    fab.read("k")
    d = reg.delta(key, fab)
    assert d["reads"] == 1 and d["writes"] == 1
    reg.observe(key, "total", 2e-3)
    s = reg.summary()["array/smoke"]
    assert s["latency"]["total"]["count"] == 1
    assert s["counters"]["reads"] == fab.stats()["reads"]


# ------------------------------------------------------- trace well-formed
def _traced_fabric_batch():
    """Run one miss-heavy + one all-hit batch under a scoped tracer."""
    fab = ArrayFabric(FabricConfig(n_shards=4, rd_lease=8, wr_lease=4))
    hot = [f"k/{i}" for i in range(32)]
    fab.write_batch([(k, f"{k}@0") for k in hot], replica=0)
    fab.fence()
    tr = obs_trace.Tracer(enabled=True)
    old = obs_trace.set_tracer(tr)
    try:
        fab.read_batch(hot, replica=1)             # misses -> miss pass
        fab.read_batch(hot, replica=1)             # all-hit fast path
    finally:
        obs_trace.set_tracer(old)
    return tr


def test_trace_spans_form_a_wellnested_forest():
    tr = _traced_fabric_batch()
    names = {e[0] for e in tr.events}
    assert {"fabric.pack", "fabric.fast_probe", "fabric.decode",
            "fabric.miss_pass", "fabric.scan",
            "fabric.scan.device"} <= names
    # per-thread, spans nest strictly: sweep by start time with a stack
    # of (start, end) — every span lies inside its enclosing one
    by_tid = {}
    for name, _cat, tid, t0, dur, _depth, _args in tr.events:
        by_tid.setdefault(tid, []).append((t0, t0 + dur, name))
    for spans in by_tid.values():
        spans.sort(key=lambda s: (s[0], -s[1]))
        stack = []
        for t0, t1, name in spans:
            while stack and t0 >= stack[-1][1]:
                stack.pop()
            assert not stack or t1 <= stack[-1][1], \
                f"{name} crosses its parent"
            stack.append((t0, t1))


def test_trace_exports_valid_chrome_json(tmp_path):
    tr = _traced_fabric_batch()
    path = tr.export(tmp_path / "trace.json")
    blob = json.loads(path.read_text())
    assert blob["displayTimeUnit"] == "ms"
    events = blob["traceEvents"]
    assert events, "no events exported"
    for ev in events:
        assert ev["ph"] == "X"                     # complete events
        assert isinstance(ev["name"], str) and isinstance(ev["cat"], str)
        assert isinstance(ev["pid"], int) and isinstance(ev["tid"], int)
        assert isinstance(ev["ts"], float) and ev["ts"] >= 0
        assert isinstance(ev["dur"], float) and ev["dur"] >= 0
        assert set(ev) <= {"name", "cat", "ph", "ts", "dur", "pid",
                           "tid", "args"}
    # the device-execute child sits inside its dispatch span
    scans = [e for e in events if e["name"] == "fabric.scan"]
    fences = [e for e in events if e["name"] == "fabric.scan.device"]
    assert scans and fences
    s, f = scans[0], fences[0]
    assert s["ts"] <= f["ts"] and \
        f["ts"] + f["dur"] <= s["ts"] + s["dur"] + 1e-3


def test_disabled_tracing_records_nothing_and_passes_values():
    tr = obs_trace.Tracer(enabled=False)
    old = obs_trace.set_tracer(tr)
    try:
        with obs_trace.span("x"):
            pass
        sentinel = object()
        assert obs_trace.fence(sentinel) is sentinel
        obs_trace.instant("y")
    finally:
        obs_trace.set_tracer(old)
    assert tr.events == []


# --------------------------------------------------------- <1% overhead gate
def test_disabled_overhead_under_one_percent_of_serving_batch():
    """The acceptance gate: spans-per-batch on the batched serving path
    x the measured cost of one DISABLED span < 1% of the batch's p50.
    (Methodology in DESIGN.md §10 — the uninstrumented build no longer
    exists to A/B against, and this decomposition is noise-immune.)"""
    cfg = FabricConfig(n_shards=4, rd_lease=64, wr_lease=4,
                       replica_sets=512, replica_ways=8,
                       shared_sets=1024, shared_ways=8)
    fab = ArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    hot = [f"prefix/{i}" for i in range(2048)]
    fab.write_batch([(k, f"{k}@0") for k in hot], replica=0)
    fab.fence()
    fab.read_batch(hot, replica=1)                 # fill + compile
    h = LatencyHistogram()
    import time
    for _ in range(12):
        t0 = time.perf_counter()
        fab.read_batch(hot, replica=1)             # all-hit steady state
        h.record(time.perf_counter() - t0)
    p50_us = h.summary()["p50_us"]
    # count the spans this exact path executes
    tr = obs_trace.Tracer(enabled=True)
    old = obs_trace.set_tracer(tr)
    try:
        fab.read_batch(hot, replica=1)
    finally:
        obs_trace.set_tracer(old)
    spans = len(tr.events)
    assert spans >= 4                              # pack/probe/donate/decode
    span_ns = obs_trace.disabled_span_cost_ns()
    overhead_pct = 100.0 * (spans * span_ns / 1e3) / p50_us
    assert overhead_pct < 1.0, (
        f"{spans} spans x {span_ns:.0f}ns = "
        f"{spans * span_ns / 1e3:.1f}us on a {p50_us:.0f}us batch "
        f"({overhead_pct:.2f}% > 1%)")


# ------------------------------------------------------------------ xprof
def test_jaxpr_collectives_counts_loop_bodies():
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh, PartitionSpec

    from repro.sharding import shard_map

    def body(c, x):
        return c + jax.lax.psum(x, "i"), x

    def fn(xs):
        c, _ = jax.lax.scan(body, jnp.float32(0), xs)
        return c + jax.lax.psum(c, "i")

    mesh = Mesh(np.array(jax.devices()[:1]), ("i",))
    jaxpr = jax.make_jaxpr(
        shard_map(fn, mesh, in_specs=PartitionSpec("i"),
                  out_specs=PartitionSpec(), check_vma=False)
    )(jnp.ones((8,), jnp.float32))
    c = jaxpr_collectives(jaxpr)
    assert c["total"] == 2 and c["in_loop"] == 1 and c["loops"] >= 1
    assert sum(c["by_primitive"].values()) == c["total"]

    # pipeline.collective_counts now delegates here: same numbers
    from repro.coherence.fabric.pipeline import collective_counts
    legacy = collective_counts(jaxpr)
    assert legacy == {"total": c["total"], "in_loop": c["in_loop"]}


def test_cost_probe_reports_structure_and_cost():
    import jax.numpy as jnp

    def fn(a, b):
        return a @ b

    a = jnp.ones((64, 64), jnp.float32)
    probe = cost_probe(fn, a, a)
    assert probe["collectives"]["total"] == 0
    # XLA's cost analysis is backend-dependent; when present it must see
    # the matmul's FLOPs
    if probe["flops"] is not None:
        assert probe["flops"] >= 2 * 64 ** 3 * 0.9
    if probe["bytes_accessed"] is not None:
        assert probe["bytes_accessed"] > 0
