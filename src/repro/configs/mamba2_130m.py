"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.
[arXiv:2405.21060] 24L d_model=768 d_ff=0 vocab=50280 ssm_state=128."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_head=0,
    d_ff=0, vocab=50280, tie_embeddings=True,
    ssm_state=128, ssm_head_dim=64, ssm_groups=1, d_conv=4, expand=2,
)
