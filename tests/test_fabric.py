"""Coherence-fabric tests: sharded TSU service, two-tier client caches,
write queue/fence, overflow reinit, and the kv_lease/lease_sync adapters."""
import dataclasses

import pytest

from repro.core import engine, protocol
from repro.coherence.fabric import (FabricConfig, ReplicaCache, SharedCache,
                                    TSUFabric, WriteQueue, stable_hash)
from repro.coherence.kv_lease import AuthoritativeStore, LeaseKVCache
from repro.coherence.lease_sync import LeaseClock


def two_tier(rd=8, wr=4, **kw):
    fabric = TSUFabric(FabricConfig(n_shards=4, rd_lease=rd, wr_lease=wr,
                                    max_in_flight=kw.pop("max_in_flight", 0),
                                    **kw))
    node = SharedCache(fabric, node_id=0)
    return fabric, node, ReplicaCache(node)


# ------------------------------------------------------------- TSU rules
def test_write_bumps_memts_fig5_plus_one():
    """Fig. 5 convention: a write from memts=m grants wts=m+1, rts=m+wr."""
    fabric = TSUFabric(FabricConfig(n_shards=1, wr_lease=5, rd_lease=10))
    g1 = fabric.write("x", "a")
    assert (g1.wts, g1.rts) == (1, 5)
    assert fabric.memts("x") == 5
    g2 = fabric.write("x", "b")               # memts=5 -> wts=6, rts=10
    assert (g2.wts, g2.rts) == (6, 10)
    g3 = fabric.read("x")                     # memts=10 -> [10, 20]
    assert (g3.wts, g3.rts) == (10, 20)
    assert fabric.memts("x") == 20


def test_shard_routing_stable_and_spread():
    f1 = TSUFabric(FabricConfig(n_shards=8))
    f2 = TSUFabric(FabricConfig(n_shards=8))
    keys = [f"key/{i}" for i in range(256)]
    routes = [f1.shard_of(k) for k in keys]
    assert routes == [f2.shard_of(k) for k in keys]           # deterministic
    assert routes == [stable_hash(k) % 8 for k in keys]       # documented fn
    assert len(set(routes)) == 8                              # actually spreads
    for k in keys:
        f1.write(k, k)
        assert k in f1.shards[f1.shard_of(k)].entries         # lands at home


def test_tsu_victim_eviction_reinitializes():
    fabric = TSUFabric(FabricConfig(n_shards=1, tsu_capacity=4, wr_lease=4))
    for i in range(8):
        fabric.write(f"k{i}", i)
    assert len(fabric.shards[0].entries) == 4
    assert fabric.stats.tsu_evictions == 4
    # an evicted key restarts from memts=0: first write grants wts=1
    assert fabric.write("k0", "again").wts == 1


# ------------------------------------------------------- overflow reinit
def test_overflow_reinit_regression_host_stores():
    """Host-side stores used to let memts exceed TS_MAX unbounded; the
    fabric applies the 16-bit reinit on every grant."""
    store = AuthoritativeStore(rd_lease=8, wr_lease=5000)
    for i in range(40):
        store.write("p", i)
    assert store.blocks["p"].memts <= protocol.TS_MAX
    assert store.fabric.stats.overflow_reinits >= 2

    clock = LeaseClock()
    for _ in range(40):
        clock.on_sync(5000)
    assert clock.memts <= protocol.TS_MAX

    big = TSUFabric(FabricConfig(n_shards=1, rd_lease=protocol.TS_MAX))
    big.write("x", 0)
    g = big.read("x")                     # would land past TS_MAX -> reinit
    g = big.read("x")
    assert big.memts("x") <= protocol.TS_MAX
    assert g.rts <= protocol.TS_MAX


# ----------------------------------------------------- two-tier caching
def test_lease_expiry_forces_refetch_and_stale_served_locally():
    fabric, node, r = two_tier(rd=8, wr=4)
    w = ReplicaCache(node)
    w.put("p", "v1")
    assert r.get("p")[0] == "v1"
    w.put("p", "v2")
    # stale read within the lease is served locally (no MM traffic)
    mm_before = fabric.stats.l2_to_mm
    assert r.get("p")[0] == "v1"
    assert fabric.stats.l2_to_mm == mm_before
    assert r.stats.l1_hits == 1
    # clock past rts -> self-invalidation -> refetch returns the new version
    r.cts = fabric.memts("p") + 1
    node.cts = fabric.memts("p") + 1
    assert r.get("p")[0] == "v2"
    assert r.stats.coh_miss_l1 >= 1
    assert fabric.stats.inval_msgs == 0          # never any invalidations


def test_replica_miss_hits_node_shared_tier():
    fabric, node, r1 = two_tier()
    r2 = ReplicaCache(node)
    r1.put("p", "v1")
    mm_before = fabric.stats.l2_to_mm
    assert r2.get("p")[0] == "v1"                # L1 miss, L2 hit: no MM trip
    assert fabric.stats.l2_to_mm == mm_before
    assert r2.stats.l2_hits == 1 and r2.stats.compulsory == 1


def test_capacity_eviction_uses_victim_way():
    fabric = TSUFabric(FabricConfig(n_shards=1, replica_sets=1,
                                    replica_ways=2, max_in_flight=0))
    node = SharedCache(fabric)
    r = ReplicaCache(node)
    for i in range(4):
        r.put(f"k{i}", i)
    assert r.stats.capacity_evictions >= 2       # 1 set x 2 ways
    # most-recently-used lines survive
    assert r.get("k3")[0] == 3
    assert r.stats.l1_hits == 1


# ------------------------------------------------------ write queue/fence
def test_write_queue_bounded_in_flight_and_fence():
    fabric = TSUFabric(FabricConfig(n_shards=2, max_in_flight=4))
    node = SharedCache(fabric)
    r = ReplicaCache(node)
    for i in range(3):
        r.put(f"k{i}", i)
    assert len(node.queue) == 3                  # posted, not yet through
    assert fabric.memts("k0") == 0
    assert r.get("k0")[0] == 0                   # store-buffer forwarding
    for i in range(3, 8):
        r.put(f"k{i}", i)                        # exceeds bound -> drains FIFO
    assert len(node.queue) == 4
    assert fabric.memts("k0") > 0                # oldest drained first
    fabric.barrier()
    assert len(node.queue) == 0
    assert all(fabric.memts(f"k{i}") > 0 for i in range(8))
    assert fabric.stats.fences == 1


def test_fence_jumps_clocks_to_global_max():
    fabric, node, r1 = two_tier()
    r2 = ReplicaCache(node)
    r1.put("p", "v1")
    assert r1.cts > r2.cts                       # writer's clock advanced
    fabric.barrier()
    assert r2.cts == r1.cts == node.cts          # kernel-boundary jump
    # post-fence, r2 cannot be served a pre-write lease it never held
    assert r2.get("p")[0] == "v1"


# ------------------------------------------------------------ telemetry
def test_fabric_stats_match_engine_counters():
    from repro.coherence.fabric.stats import FabricStats
    names = {f.name for f in dataclasses.fields(FabricStats)}
    assert set(engine.COUNTERS) <= names
    fabric, node, r = two_tier()
    r.put("a", 1)
    r.get("a")
    view = fabric.stats.engine_view()
    assert list(view) == list(engine.COUNTERS)
    assert view["writes"] == 1 and view["reads"] == 1
    assert view["wb_evictions"] == 0 and view["inval_msgs"] == 0


# ------------------------------------------------------------- adapters
def test_kv_lease_adapter_routes_through_fabric():
    store = AuthoritativeStore(rd_lease=8, wr_lease=4)
    kv = LeaseKVCache(store, capacity=16)
    kv.put("p", "v1")
    assert store.fabric.stats.write_throughs == 1
    assert kv.get("p")[0] == "v1"
    assert kv.stats["hits"] == 1
    # legacy surface preserved: blocks view + store read/write
    assert store.blocks["p"].version == 1
    wts, rts = store.write("p", "v2")
    assert wts == store.blocks["p"].memts - 4 + 1


def test_store_write_visible_after_reader_fence():
    """Upstream recompute via store.write must reach fenced readers: the
    grant is adopted into the node tier (clock advance), so the shared
    line cannot stay 'valid' forever."""
    store = AuthoritativeStore(rd_lease=8, wr_lease=4)
    kv = LeaseKVCache(store)
    kv.put("p", "v1")
    assert kv.get("p")[0] == "v1"
    store.write("p", "v2")                     # bypasses the replicas
    kv.cts = store.blocks["p"].memts + 1       # reader fence
    assert kv.get("p")[0] == "v2"


def test_store_lease_args_conflict_with_fabric_raises():
    fabric = TSUFabric(FabricConfig(n_shards=1, rd_lease=8, wr_lease=4))
    with pytest.raises(ValueError, match="conflict"):
        AuthoritativeStore(rd_lease=100, fabric=fabric)
    s = AuthoritativeStore(rd_lease=8, wr_lease=4, fabric=fabric)
    assert s.rd_lease == 8                     # matching args are fine


def test_fabric_registrations_are_weak():
    import gc
    fabric = TSUFabric(FabricConfig(n_shards=1, max_in_flight=0))
    node = SharedCache(fabric)
    r = ReplicaCache(node)
    r.put("k", 1)
    del r, node
    gc.collect()
    assert fabric.barrier() == 0               # dead caches pruned, no crash
    assert all(ref() is None for ref in fabric._caches) or not fabric._caches


def test_pending_write_version_is_none_until_drain():
    fabric = TSUFabric(FabricConfig(n_shards=1, max_in_flight=4))
    r = ReplicaCache(SharedCache(fabric))
    r.put("k", "v")
    assert r.get("k") == ("v", None)           # in flight: no fake version
    r.fence()
    assert r.get("k") == ("v", 1)


def test_lease_clock_adapter_memts_and_lease():
    clock = LeaseClock()
    lease = clock.on_sync(4)
    assert (int(lease.wts), int(lease.rts)) == (1, 4)
    assert clock.memts == 4
    lease = clock.on_sync(4)
    assert int(lease.wts) == 5                    # Fig. 5 +1 ordering


def test_server_and_trainer_share_fabric_surface():
    """Both runtimes expose the same FabricStats counter names, now via the
    array backend; the server issues batched lease probes."""
    import jax
    import numpy as np
    from repro.coherence.fabric import ArrayFabric
    from repro import configs as cfgs
    from repro.models import init_model
    from repro.runtime.server import Request, Server

    cfg = cfgs.SMOKE["smollm-360m"]
    params = init_model(cfg, jax.random.PRNGKey(0))
    fabric = ArrayFabric(FabricConfig(n_shards=2))
    srv = Server(cfg, params, batch_size=2, max_len=32, fabric=fabric)
    prompt = np.arange(2, 10).astype(np.int32)
    srv.serve([Request(rid=0, prompt=prompt, max_new=2)])
    srv.kv.fence()                       # drain the posted write-through
    assert srv.fabric_stats["write_throughs"] >= 1
    assert set(engine.COUNTERS) <= set(srv.fabric_stats)
    # repeated serve is a lease hit — no new prefill write-through
    wt = srv.fabric_stats["write_throughs"]
    srv.serve([Request(rid=1, prompt=prompt, max_new=2)])
    srv.kv.fence()
    assert srv.fabric_stats["write_throughs"] == wt
    assert srv.cache_stats["hits"] >= 1
