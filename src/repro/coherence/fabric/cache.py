"""Two-tier client caching over the TSU fabric: ReplicaCache over SharedCache.

Mirrors the simulator's L1-over-L2 hierarchy (engine.py) on the host:

  ReplicaCache  — a replica's private tier (the CU's L1): per-cache logical
                  clock ``cts``, set-associative with LRU + victim-way
                  eviction, write-through (writes always descend).
  SharedCache   — the node-shared tier (the GPU's L2): same structure, plus
                  the node's bounded async write queue to the fabric.

Coherence is pure HALCONE: a line is served while ``cts <= rts`` (tag match
alone is not enough); expiry *self-invalidates* — the line is dropped and
refetched from below, and no invalidation message ever travels between
caches (``FabricStats.inval_msgs`` stays 0 by construction).  All timestamp
arithmetic is ``repro.core.protocol``; the tiers only move lines.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Tuple

from repro.core import protocol
from repro.core.state import BLOCK_BYTES
from repro.coherence.fabric.stats import FabricStats
from repro.coherence.fabric.tsu import LeaseGrant, TSUFabric, stable_hash
from repro.coherence.fabric.writeq import WriteQueue


@dataclasses.dataclass
class _Line:
    key: Any
    value: Any
    version: Optional[int]   # None while a posted write is in flight
    wts: int
    rts: int
    lru: int = 0
    pending: bool = False    # posted write not yet through the fabric


class _SetAssoc:
    """Host-side set-associative store with the engine's victim rule:
    invalid ways first, else the least-recently-used live way."""

    def __init__(self, sets: int, ways: int):
        self.n_sets, self.n_ways = max(1, sets), max(1, ways)
        self._sets: List[List[Optional[_Line]]] = [
            [None] * self.n_ways for _ in range(self.n_sets)]
        self._tick = 0

    def _row(self, key) -> List[Optional[_Line]]:
        return self._sets[stable_hash(key) % self.n_sets]

    def probe(self, key) -> Optional[_Line]:
        for line in self._row(key):
            if line is not None and line.key == key:
                self._tick += 1
                line.lru = self._tick
                return line
        return None

    def install(self, line: _Line) -> bool:
        """Place (or refresh) a line; returns True iff a live line with a
        DIFFERENT key was displaced (a capacity eviction)."""
        row = self._row(line.key)
        self._tick += 1
        line.lru = self._tick
        victim, score = 0, None
        for w, cur in enumerate(row):
            if cur is not None and cur.key == line.key:
                row[w] = line
                return False
            s = -1 if cur is None else cur.lru     # invalid ways first
            if score is None or s < score:
                victim, score = w, s
        evicted = row[victim] is not None
        row[victim] = line
        return evicted

    def drop(self, key) -> None:
        row = self._row(key)
        for w, cur in enumerate(row):
            if cur is not None and cur.key == key:
                row[w] = None
                return


def _bump(stats: List[FabricStats], name: str, by: int = 1) -> None:
    for s in stats:
        s.bump(name, by)


class SharedCache:
    """Node-shared tier: one per node, fed by that node's write queue."""

    def __init__(self, fabric: TSUFabric, node_id: int = 0,
                 sets: Optional[int] = None, ways: Optional[int] = None,
                 max_in_flight: Optional[int] = None):
        cfg = fabric.cfg
        self.fabric = fabric
        self.node_id = node_id
        self.home_shard = node_id % cfg.n_shards
        self.cts = 0
        self._store = _SetAssoc(sets or cfg.shared_sets,
                                ways or cfg.shared_ways)
        self.queue = WriteQueue(fabric, max_in_flight)
        fabric.attach(self)

    def adopt(self, key, value, grant: LeaseGrant) -> LeaseGrant:
        """Install a fresh MM grant into this tier and advance the node clock
        (the write side of the engine's L2 install).  Used by the drain path
        and by authorities that publish around the queue."""
        lease = protocol.install(self.cts, grant.wts, grant.rts)
        wts, rts = int(lease.wts), int(lease.rts)
        self.cts = int(protocol.cts_after_write(self.cts, wts))
        if self._store.install(_Line(key, value, grant.version, wts, rts)):
            self.fabric.stats.bump("capacity_evictions")
        return LeaseGrant(value, grant.version, wts, rts, grant.shard)

    def get(self, key, mirror: Optional[FabricStats] = None
            ) -> Optional[Tuple[Any, int, int, int]]:
        """Returns (value, version, wts, rts) with the lease this tier holds,
        or None if the fabric has no such block."""
        stats = [self.fabric.stats] + ([mirror] if mirror else [])
        line = self._store.probe(key)
        if line is not None:
            if protocol.valid(self.cts, line.rts):
                _bump(stats, "l2_hits")
                return line.value, line.version, line.wts, line.rts
            _bump(stats, "coh_miss_l2")
            _bump(stats, "self_invalidations")
            self._store.drop(key)
        grant = self.fabric.read(key, home_shard=self.home_shard)
        if grant is None:
            return None
        lease = protocol.install(self.cts, grant.wts, grant.rts)
        wts, rts = int(lease.wts), int(lease.rts)
        if self._store.install(_Line(key, grant.value, grant.version,
                                     wts, rts)):
            _bump(stats, "capacity_evictions")
        return grant.value, grant.version, wts, rts

    def put(self, key, value, on_complete=None, *,
            wr_lease: Optional[int] = None) -> None:
        """Posted write-through: queue the fabric write; on drain, install the
        granted lease here and advance this node's clock before notifying the
        writer (the engine's L2-then-L1 install order)."""

        def _drained(grant: LeaseGrant) -> None:
            installed = self.adopt(key, value, grant)
            if on_complete is not None:
                on_complete(installed)

        self.queue.submit(key, value, _drained, wr_lease=wr_lease,
                          home_shard=self.home_shard)

    def fence(self) -> int:
        return self.queue.fence()


class ReplicaCache:
    """A replica's private tier over the node's SharedCache."""

    def __init__(self, shared: SharedCache,
                 sets: Optional[int] = None, ways: Optional[int] = None):
        cfg = shared.fabric.cfg
        self.shared = shared
        self.cts = 0
        self.stats = FabricStats()       # per-replica view of the same names
        self._store = _SetAssoc(sets or cfg.replica_sets,
                                ways or cfg.replica_ways)
        shared.fabric.attach(self)

    def _stats(self) -> List[FabricStats]:
        return [self.shared.fabric.stats, self.stats]

    def peek(self, key) -> bool:
        """Non-mutating lease check: True iff ``get`` would be served from
        this tier (tag match AND live lease).  No LRU touch, no counters —
        the probe half of the batched read's phase split (backend.py)."""
        for line in self._store._row(key):
            if line is not None and line.key == key:
                return bool(protocol.valid(self.cts, line.rts))
        return False

    def get(self, key) -> Optional[Tuple[Any, int]]:
        stats = self._stats()
        _bump(stats, "reads")
        line = self._store.probe(key)
        if line is not None:
            if protocol.valid(self.cts, line.rts):
                _bump(stats, "l1_hits")
                return line.value, line.version
            _bump(stats, "coh_miss_l1")
            _bump(stats, "self_invalidations")
            self._store.drop(key)
        else:
            _bump(stats, "compulsory")
        _bump(stats, "l1_to_l2")
        # link bytes accrue on the fabric-global view only (the per-replica
        # mirror keeps the simulator-shared subset)
        self.shared.fabric.stats.bump("bytes_l1_l2", BLOCK_BYTES)
        got = self.shared.get(key, mirror=self.stats)
        if got is None:
            return None
        value, version, wts, rts = got
        lease = protocol.install(self.cts, wts, rts)
        _bump(stats, "refetches")
        if self._store.install(_Line(key, value, version,
                                     int(lease.wts), int(lease.rts))):
            _bump(stats, "capacity_evictions")
        return value, version

    def put(self, key, value, *, wr_lease: Optional[int] = None) -> None:
        stats = self._stats()
        _bump(stats, "writes")
        _bump(stats, "l1_to_l2")         # write-through: writes descend
        self.shared.fabric.stats.bump("bytes_l1_l2", BLOCK_BYTES)

        def _installed(grant: LeaseGrant) -> None:
            lease = protocol.install(self.cts, grant.wts, grant.rts)
            wts, rts = int(lease.wts), int(lease.rts)
            self.cts = int(protocol.cts_after_write(self.cts, wts))
            # the fabric already counted this write-through at the drain;
            # mirror it into the per-replica view only.
            self.stats.bump("write_throughs")
            if self._store.install(_Line(key, value, grant.version,
                                         wts, rts)):
                _bump(stats, "capacity_evictions")

        # store-buffer forwarding: own reads see the posted write while it is
        # in flight (version None until the fabric assigns one); the
        # provisional lease dies as soon as cts advances.
        if self._store.install(_Line(key, value, None, self.cts, self.cts,
                                     pending=True)):
            _bump(stats, "capacity_evictions")
        self.shared.put(key, value, _installed, wr_lease=wr_lease)

    def fence(self) -> int:
        return self.shared.fence()
