"""Trace generators: the paper's Fig.5 litmus scenarios, the Xtreme synthetic
suite (§4.3.2, reproduced exactly at block granularity), and generative models
of the 11 standard benchmarks (Table 3).

The 11 standard benchmarks (STANDARD) map back to the paper's Table 3 suite
(Hetero-Mark, PolyBench, SHOC and AMDAPPSDK workloads) by footprint and
memory-intensity class:

  =====  ==========================  =========  ========  ==================
  key    workload                    footprint  class     mix notes
  =====  ==========================  =========  ========  ==================
  aes    AES-256 encryption           71 MB     compute   table-lookup reuse
  atax   matrix-vector (A^T A x)      64 MB     memory    streaming, shared A
  bfs    breadth-first search        574 MB     memory    irregular, shared
                                                          frontier (70%)
  bicg   BiCGStab sub-kernels         64 MB     compute   two streamed MVs
  bs     black-scholes                67 MB     memory    50% writes, in-place
  fir    FIR filter                   67 MB     memory    sliding-window reuse
  fws    Floyd-Warshall               32 MB     memory    in-place shared
                                                          matrix (80% shared)
  mm     matrix multiply             192 MB     memory    tiled reuse (55%)
  mp     MaxPool                      64 MB     compute   dense conv-style
  rl     ReLU                         67 MB     memory    pure streaming
  conv   convolution                 145 MB     memory    stencil reuse (50%)
  =====  ==========================  =========  ========  ==================

Block granularity: one READ/WRITE per 64 B block touched; the 16 fp32 elements
a block holds are folded into a COMPUTE op (ALU + L1-hit cycles), which keeps
round counts tractable without changing miss behaviour.

For the batched figure engine (DESIGN.md §5) a set of per-benchmark traces is
padded to one dense ``[B, NC, R]`` tensor by ``pack_batch``: every trace is
right-padded with NOPs to the longest round count (NOP rounds advance no
state, no time and no counters, so padding is exact, not approximate), and
the batch axis becomes the vmapped benchmark axis of ``engine.sweep``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

from repro.core.engine import COMPUTE, FENCE, NOP, READ, WRITE
from repro.core.sysconfig import SystemConfig


def _pack(streams: List[List[Tuple[int, int]]]) -> Tuple[np.ndarray, np.ndarray]:
    """streams[cu] = [(op, addr), ...] -> padded [NC, T] arrays."""
    T = max(len(s) for s in streams)
    ops = np.zeros((len(streams), T), np.int32)
    addrs = np.zeros((len(streams), T), np.int32)
    for i, s in enumerate(streams):
        for t, (o, a) in enumerate(s):
            ops[i, t] = o
            addrs[i, t] = a
    return ops, addrs


def pack_batch(trace_list) -> Tuple[np.ndarray, np.ndarray]:
    """[(ops [NC, T_i], addrs [NC, T_i]), ...] -> ([B, NC, R], [B, NC, R]).

    Pads every trace with NOPs to the longest round count R so a benchmark
    batch is one dense tensor — the vmapped benchmark axis of
    ``engine.sweep``.  All traces must share NC (one CU grid per sweep)."""
    trace_list = list(trace_list)
    NC = trace_list[0][0].shape[0]
    R = max(o.shape[1] for o, _ in trace_list)
    B = len(trace_list)
    ops = np.zeros((B, NC, R), np.int32)
    addrs = np.zeros((B, NC, R), np.int32)
    for b, (o, a) in enumerate(trace_list):
        if o.shape[0] != NC:
            raise ValueError(f"trace {b} has NC={o.shape[0]}, expected {NC}")
        ops[b, :, :o.shape[1]] = o
        addrs[b, :, :a.shape[1]] = a
    return ops, addrs


# ------------------------------------------------------------------ litmus
def litmus_intra(cfg: SystemConfig):
    """Fig 5(a): CU0/CU1 of GPU0; X=5, Y=9 (distinct blocks, same GPU)."""
    X, Y = 5, 9
    s0 = [(READ, X), (WRITE, Y), (READ, X)]
    s1 = [(READ, Y), (WRITE, X), (READ, Y)]
    streams = [s0, s1] + [[] for _ in range(cfg.n_cus - 2)]
    # stagger exactly as the figure: I1-2 after I0-2, I1-3 after I0-3
    s0i = [s0[0], (NOP, 0), s0[1], s0[2], (NOP, 0), (NOP, 0)]
    s1i = [(NOP, 0), s1[0], (NOP, 0), (NOP, 0), s1[1], s1[2]]
    streams = [s0i, s1i] + [[(NOP, 0)] for _ in range(cfg.n_cus - 2)]
    return _pack(streams)


def litmus_inter(cfg: SystemConfig):
    """Fig 5(b): CU0 of GPU0 vs CU0 of GPU1 — same instructions.

    X and Y map to the SAME L2 bank (the paper's walkthrough treats the L2 as
    one logical cache with one cts; Table 2's per-bank clocks only see writes
    that route through the same bank — DESIGN.md §4 records this subtlety).
    """
    X, Y = 5, 5 + cfg.l2_banks
    s0 = [(READ, X), (NOP, 0), (WRITE, Y), (READ, X), (NOP, 0), (NOP, 0)]
    s1 = [(NOP, 0), (READ, Y), (NOP, 0), (NOP, 0), (WRITE, X), (READ, Y)]
    streams = [[(NOP, 0)] for _ in range(cfg.n_cus)]
    streams[0] = s0
    streams[cfg.cus_per_gpu] = s1            # CU0 of GPU1
    return _pack(streams)


# ------------------------------------------------------------------ Xtreme
@dataclasses.dataclass
class XtremeSpec:
    variant: int                  # 1 | 2 | 3
    blocks_per_slice: int         # slice size in 64B blocks (touched set)
    reps: int = 10
    compute_cycles: int = 160     # 16 elems x ~10 cycles FP+addressing each


def xtreme(cfg: SystemConfig, spec: XtremeSpec):
    """C = A + B with repeated writes (paper §4.3.2).

    Slices are assigned per-CU; variant 1 = private, 2 = intra-GPU sharing
    (CU_X0 writes CU_X1's slice), 3 = inter-GPU sharing (CU_X0 writes
    CU_Y1's slice).  FENCEs mark the kernel boundaries between steps.
    """
    NC = cfg.n_cus
    nb = spec.blocks_per_slice
    base_a, base_b, base_c = 0, NC * nb, 2 * NC * nb

    def slice_blocks(i):
        return np.arange(i * nb, (i + 1) * nb)

    def pass_over(i, dst_base, src1, src2, sl):
        out = []
        for b in sl:
            out += [(READ, src1 + b), (READ, src2 + b),
                    (COMPUTE, spec.compute_cycles), (WRITE, dst_base + b)]
        return out

    streams: List[List[Tuple[int, int]]] = [[] for _ in range(NC)]
    # step 1: every CU computes C_i = A_i + B_i on its own slice
    for i in range(NC):
        streams[i] += pass_over(i, base_c, base_a, base_b, slice_blocks(i))
    for i in range(NC):
        streams[i].append((FENCE, 0))

    if spec.variant == 1:
        # repeat step1 `reps` times, then A_i = C_i + B_i repeated
        for _ in range(spec.reps - 1):
            for i in range(NC):
                streams[i] += pass_over(i, base_c, base_a, base_b,
                                        slice_blocks(i))
        for i in range(NC):
            streams[i].append((FENCE, 0))
        for _ in range(spec.reps):
            for i in range(NC):
                streams[i] += pass_over(i, base_a, base_c, base_b,
                                        slice_blocks(i))
    else:
        victim = 1 if spec.variant == 2 else (cfg.cus_per_gpu + 1) % NC
        sl = slice_blocks(victim)
        for _ in range(spec.reps):
            streams[0] += pass_over(0, base_a, base_c, base_b, sl)
        for i in range(NC):
            streams[i].append((FENCE, 0))
        for i in range(NC):
            streams[i] += pass_over(i, base_c, base_a, base_b,
                                    slice_blocks(i))
    return _pack(streams)


# ------------------------------------------- standard benchmarks (Table 3)
@dataclasses.dataclass(frozen=True)
class BenchModel:
    name: str
    footprint_mb: float
    kind: str                 # "compute" | "memory"
    write_frac: float         # fraction of mem ops that write
    compute_per_mem: int      # COMPUTE cycles per memory op
    shared_frac: float        # accesses falling in the GPU-interleaved region
    reuse: float              # probability of re-touching a recent block
    rw_share: float = 0.05    # fraction of writes to read-write shared data
                              # (in-place algorithms: fws, bs ...)


# Type and footprints from Table 3; access-mix parameters follow each
# benchmark's published characterization (streaming reads, stencil reuse...).
# rw_share: the in-place algorithms (Floyd-Warshall's shared distance
# matrix, Black-Scholes' in-place price updates) write READ-WRITE SHARED
# data — the accesses that actually need coherence.  Calibrated against
# the paper's Fig 7 bars: large enough that the speedup sweeps exercise
# real write-sharing coherence misses (HMG pays invalidations, HALCONE
# self-invalidates — nonzero coh_miss counters), small enough that
# HALCONE stays within the paper's ~1%-overhead band of SM-WT-NC (our
# generative hot slice is far hotter than the paper's real traces, so a
# literal 80%-shared fws would overstate the coherence penalty ~10x).
# The streaming mixes stay at 0 (disjoint output slices, §5.1) and are
# bit-identical to the pre-hot-slice generator.
STANDARD: Dict[str, BenchModel] = {
    "aes":  BenchModel("aes", 71, "compute", 0.25, 220, 0.10, 0.30, 0.000),
    "atax": BenchModel("atax", 64, "memory", 0.10, 12, 0.50, 0.20, 0.000),
    "bfs":  BenchModel("bfs", 574, "memory", 0.15, 10, 0.70, 0.05, 0.000),
    "bicg": BenchModel("bicg", 64, "compute", 0.10, 150, 0.50, 0.20, 0.000),
    "bs":   BenchModel("bs", 67, "memory", 0.50, 14, 0.60, 0.10, 0.010),
    "fir":  BenchModel("fir", 67, "memory", 0.33, 16, 0.30, 0.40, 0.000),
    "fws":  BenchModel("fws", 32, "memory", 0.33, 12, 0.80, 0.15, 0.020),
    "mm":   BenchModel("mm", 192, "memory", 0.05, 40, 0.60, 0.55, 0.000),
    "mp":   BenchModel("mp", 64, "compute", 0.25, 160, 0.20, 0.25, 0.000),
    "rl":   BenchModel("rl", 67, "memory", 0.50, 10, 0.20, 0.10, 0.000),
    "conv": BenchModel("conv", 145, "memory", 0.12, 30, 0.50, 0.50, 0.000),
}


def standard_trace(cfg: SystemConfig, bench: BenchModel, rounds: int = 1536,
                   seed: int = 0):
    """Generative streaming trace with the benchmark's mix.

    Addresses: each GPU owns a private region sized by footprint share; a
    shared region (interleaved pages) receives `shared_frac` of accesses.
    Streaming = sequential block walk (stride 1) + `reuse` re-touches.

    ``rw_share`` benchmarks (in-place frontier/matrix updates) additionally
    target a small HOT slice at the base of the shared region with both a
    slice of their shared reads and their in-place writes — the accesses
    every GPU touches, i.e. the ones that actually exercise coherence
    (directory invalidations under HMG, self-invalidation under HALCONE;
    Fig 10).  With ``rw_share == 0`` — every STANDARD mix — the hot-slice
    paths are never taken and draw nothing from the rng, so those traces
    are bit-identical to the pre-hot-slice generator.
    """
    rng = np.random.default_rng(seed)
    NC, CU = cfg.n_cus, cfg.cus_per_gpu
    G, PB = cfg.n_gpus, cfg.page_blocks
    total_blocks = int(bench.footprint_mb * 1024 * 1024 / 64)
    # cap the address range so the sim's dense MM array stays small while
    # keeping cache-pressure >> capacity for big footprints
    total_blocks = min(total_blocks, 1 << 20)
    shared_blocks = max(1024, int(total_blocks * 0.5))
    priv_blocks = max(512, (total_blocks - shared_blocks) // cfg.n_gpus)
    priv_blocks = (priv_blocks + PB - 1) // PB * PB      # page aligned

    def priv_addr(g: int, b: int) -> int:
        # private data lives on pages OWNED by gpu g (home_gpu == g), the
        # placement a programmer uses under RDMA; SM interleaving unaffected
        page, off = divmod(b, PB)
        return (page * G + g) * PB + off

    ops = np.zeros((NC, rounds), np.int32)
    addrs = np.zeros((NC, rounds), np.int32)
    shared_base = priv_blocks * cfg.n_gpus
    gpu_start = rng.integers(0, shared_blocks, cfg.n_gpus)
    # interleave compute ops: 1 per `duty` rounds carries the compute budget
    duty = 4 if bench.kind == "compute" else 8
    half = priv_blocks // 2                      # inputs | outputs split
    for cu in range(NC):
        g = cu // CU
        pos = rng.integers(0, half)
        pos_w = rng.integers(0, half)
        # shared walks are gpu-clustered (neighbouring CUs stream the same
        # region) so temporal/spatial locality exists for caches to exploit
        pos_sh = (gpu_start[g] + (cu % CU) * 4) % shared_blocks
        recent = np.zeros(8, np.int64)
        for t in range(rounds):
            if t % 512 == 511:                 # kernel boundary (fence)
                ops[cu, t] = FENCE
                continue
            if t % duty == duty - 1:
                ops[cu, t] = COMPUTE
                addrs[cu, t] = bench.compute_per_mem * duty
                continue
            write = rng.random() < bench.write_frac
            r = rng.random()
            if write and rng.random() < bench.rw_share:
                # in-place update of shared read-write data (fws/bs-style):
                # the accesses that actually need coherence.  Targets the
                # hot slice every GPU reads (below), not this CU's private
                # walk position — otherwise no other GPU ever shares the
                # line and no protocol has anything to invalidate.
                a = shared_base + int(rng.integers(0, 2 * PB))
            elif write:
                # streaming kernels write each output once; output slices are
                # DISJOINT per CU (standard C=A+B partitioning — no write
                # sharing, which is what keeps coherency misses rare, §5.1)
                out_slice = max(16, half // CU)
                pos_w = (pos_w + 1) % out_slice
                a = priv_addr(g, half + ((cu % CU) * out_slice + pos_w)
                              % half)
            elif r < bench.reuse:
                a = recent[rng.integers(0, 8)]   # re-READ of an input
            elif r < bench.reuse + bench.shared_frac:
                # subdivide the already-drawn r: an rw_share-sized tail of
                # the shared reads hits the hot in-place slice (empty when
                # rw_share == 0 -> identical stream for streaming mixes)
                if r >= bench.reuse + bench.shared_frac * (1 - bench.rw_share):
                    a = shared_base + int(rng.integers(0, 2 * PB))
                else:
                    pos_sh = (pos_sh + 1) % shared_blocks
                    a = shared_base + pos_sh
                recent[t % 8] = a
            else:
                pos = (pos + 1) % half
                a = priv_addr(g, (pos + cu * 131) % half)
                recent[t % 8] = a
            ops[cu, t] = WRITE if write else READ
            addrs[cu, t] = a
    return ops, addrs
