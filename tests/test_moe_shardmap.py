"""shard_map MoE dispatch == the GSPMD dispatch math, judged against the
unsharded reference (8 fake devices, subprocess so the device-count flag
lands before jax init).

The comparison anchor is `_moe_gspmd` run WITHOUT a mesh: on this
container's jax 0.4.x, the GSPMD partitioner miscompiles the global-scatter
dispatch on a mixed (data x model) mesh (outputs off by ~40% of their
magnitude vs. the same math unsharded — see DESIGN.md §4), so comparing the
two mesh paths to each other would test the partitioner bug, not the
dispatch.  The shard_map path with explicit collectives is exact.
"""
import subprocess
import sys

SCRIPT = r'''
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
sys.path.insert(0, "src")
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro import configs as cfgs
from repro.models import moe as moe_mod
from repro.sharding import ShardCtx, NOSHARD
mesh = jax.make_mesh((2, 4), ("data", "model"))
ctx = ShardCtx(mesh)
cfg = dataclasses.replace(cfgs.SMOKE["deepseek-v2-236b"], n_experts=8,
                          top_k=2, capacity_factor=8.0)  # no drops => equal
spec = moe_mod.moe_spec(cfg)
from repro.models.params import materialize
p = materialize(spec, jax.random.PRNGKey(0))
h = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
ref, aref = jax.jit(lambda p, h: moe_mod._moe_gspmd(cfg, p, h, NOSHARD))(p, h)
o2, a2 = jax.jit(lambda p, h: moe_mod._moe_shard_map(cfg, p, h, ctx))(p, h)
np.testing.assert_allclose(np.asarray(ref), np.asarray(o2), rtol=2e-4, atol=2e-4)
np.testing.assert_allclose(float(aref), float(a2), rtol=0.3)  # aux: local approx
# single-mesh-axis GSPMD runs are NOT hit by the partitioner bug; pin that
mesh1 = jax.make_mesh((1, 8), ("data", "model"))
o1, a1 = jax.jit(lambda p, h: moe_mod._moe_gspmd(cfg, p, h, ShardCtx(mesh1)))(p, h)
np.testing.assert_allclose(np.asarray(ref), np.asarray(o1), rtol=2e-4, atol=2e-4)
print("MOE_MATCH_OK")
'''


def test_moe_shardmap_matches_gspmd():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=520, cwd=".")
    assert "MOE_MATCH_OK" in r.stdout, r.stdout + r.stderr
