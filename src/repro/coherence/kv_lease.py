"""Lease-coherent prefix-KV cache for multi-replica serving.

The serving-side transfer of HALCONE (DESIGN.md §2a): prefill results
(prefix KV blocks) are shared across serving replicas; replicas
*self-invalidate* on lease expiry instead of receiving invalidation
messages when a prefix is republished (model refresh, upstream eviction).

Since the array-native refactor (DESIGN.md §7) the production adapter is
``BatchedKVLease``: a thin veneer over a ``FabricBackend`` — by default
``default_fabric()``, i.e. the mesh-placed ``ShardedArrayFabric`` whenever
more than one device is visible (TSU shards execute grants on their owning
devices, DESIGN.md §8), else the single-device ``ArrayFabric`` — whose
``get_batch``/``put_batch`` issue ONE batched lease probe per decode batch
instead of a Python call per key.  ``runtime/server.py`` and
``launch/serve.py`` speak only this API.

``AuthoritativeStore`` / ``LeaseKVCache`` remain as the HOST-OBJECT
adapters over the oracle fabric — kept because the differential parity
suite (tests/test_fabric_parity.py) pins the array backend to them
bit-for-bit; they are not a production path.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.coherence.fabric import (FabricBackend, FabricConfig,
                                    ReplicaCache, SharedCache, TSUFabric,
                                    default_fabric)


class BatchedKVLease:
    """A serving replica's batched lease front end (the production path).

    One ``get_batch`` = one vectorized fabric probe for the whole decode
    batch (backend two-phase semantics: lease hits served in one
    ``state.tier_probe`` call, misses through the exact op-scan); one
    ``put_batch`` = the posted write-throughs for every freshly prefilled
    prefix.  All timestamp rules live behind the backend in
    ``core.protocol`` / ``core.state``.
    """

    def __init__(self, backend: Optional[FabricBackend] = None,
                 replica: int = 0, pipeline: Optional[str] = None):
        """``pipeline`` selects the fabric pipeline ("batched" default,
        "scan" for ordering-sensitive debugging) when this adapter builds
        its own backend; an explicit ``backend`` already carries its
        pipeline, so passing both is a conflict, not a silent no-op."""
        if backend is not None and pipeline is not None:
            raise ValueError(
                "pipeline= only applies when BatchedKVLease builds its own "
                "fabric; construct the backend with pipeline=... instead")
        self.backend = backend if backend is not None else default_fabric(
            FabricConfig(), pipeline=pipeline or "batched")
        self.replica = replica

    # ------------------------------------------------------------ batched
    def get_batch(self, keys: Sequence[str]) -> List:
        """[(value, version) | None] per key, one fabric round trip: lease
        hits from ONE vectorized probe, the miss subset from the batched
        grant pipeline's vectorized miss pass (one batched TSU grant + one
        batched fill per tier — O(1) grant collectives per batch on the
        sharded fabric, DESIGN.md §9)."""
        return self.backend.read_batch(keys, replica=self.replica)

    def get_batch_async(self, keys: Sequence[str]):
        """Dispatch ``get_batch``'s fabric work and defer the host-side
        payload decode: returns a ``ReadBatchHandle`` whose ``.result()``
        yields exactly ``get_batch``'s output.  On the sharded fabric the
        probe, miss pass and the NEXT batch's grant exchange are already
        in flight when this returns — ``Server.serve_stream``'s overlap
        boundary (DESIGN.md §12a).  Ordering contract is the backend's:
        resolve before this replica's next write/fence."""
        return self.backend.read_batch_async(keys, replica=self.replica)

    def put_batch(self, items: Sequence[Tuple[str, Any]]) -> None:
        """Post every freshly prefilled prefix as ONE write batch: the
        backend's batched write pass serves the whole storm with batched
        probes, one batched TSU write-through grant per conflict-free
        round, and — on the sharded fabric — ONE packed collective per
        call instead of one per posted write (DESIGN.md §11)."""
        self.backend.write_batch(items, replica=self.replica)

    # ------------------------------------------------------------- scalar
    def get(self, key: str):
        return self.backend.read(key, replica=self.replica)

    def put(self, key: str, value: Any) -> None:
        self.backend.write(key, value, replica=self.replica)

    def fence(self) -> int:
        return self.backend.fence()

    # ------------------------------------------------------------- views
    @property
    def stats(self) -> Dict[str, int]:
        """Legacy counter names, derived from the replica's fabric view."""
        s = self.backend.replica_stats(self.replica)
        return {"hits": s["l1_hits"],
                "coherence_misses": s["coh_miss_l1"],
                "compulsory": s["compulsory"],
                "refetches": s["refetches"],
                "capacity_evictions": s["capacity_evictions"]}

    @property
    def fabric_stats(self) -> Dict[str, int]:
        return self.backend.stats()


class AuthoritativeStore:
    """HOST-ORACLE adapter: the MM+TSU front door over the host fabric.

    Adapter over a host ``TSUFabric``; also owns the node-shared cache tier
    that every ``LeaseKVCache`` replica attached to this store reads
    through.  Used by the oracle half of the parity suite.
    """

    def __init__(self, rd_lease: Optional[int] = None,
                 wr_lease: Optional[int] = None,
                 fabric: Optional[TSUFabric] = None, node_id: int = 0):
        if fabric is None:
            fabric = TSUFabric(FabricConfig(
                n_shards=1, rd_lease=rd_lease if rd_lease is not None else 8,
                wr_lease=wr_lease if wr_lease is not None else 4,
                max_in_flight=0))
        elif ((rd_lease is not None and rd_lease != fabric.cfg.rd_lease)
              or (wr_lease is not None and wr_lease != fabric.cfg.wr_lease)):
            raise ValueError(
                "explicit rd_lease/wr_lease conflict with the supplied "
                f"fabric's config ({fabric.cfg.rd_lease}/{fabric.cfg.wr_lease})"
                "; set them on the FabricConfig instead")
        self.fabric = fabric
        self.rd_lease = self.fabric.cfg.rd_lease
        self.wr_lease = self.fabric.cfg.wr_lease
        # legacy stores write through synchronously (max_in_flight=0)
        self.shared = SharedCache(self.fabric, node_id=node_id,
                                  max_in_flight=0)

    @property
    def blocks(self) -> Dict[str, Any]:
        """Live view of the fabric's MM+TSU rows (``.value/.version/.memts``)."""
        return self.fabric.entries()

    def write(self, key: str, value: Any) -> Tuple[int, int]:
        """Publish around the replicas (upstream recompute / model refresh).
        The grant is adopted into the node tier so the node clock advances —
        otherwise a reader fencing past memts could be served the old value
        from a shared line whose lease never expires."""
        grant = self.fabric.write(key, value)
        self.shared.adopt(key, value, grant)
        return grant.wts, grant.rts

    def read(self, key: str) -> Optional[Tuple[Any, int, int, int]]:
        grant = self.fabric.read(key)
        if grant is None:
            return None
        return grant.value, grant.version, grant.wts, grant.rts


class LeaseKVCache:
    """HOST-ORACLE adapter: a replica's local cache with a logical clock.

    cts advances on every write-through this replica performs; reads hit
    while cts <= rts; expiry triggers a refetch from the node tier or the
    fabric — NO invalidation traffic ever flows between replicas.
    """

    _WAYS = 4

    def __init__(self, store: AuthoritativeStore, capacity: int = 128):
        self.store = store
        self.capacity = capacity
        self.replica = ReplicaCache(store.shared,
                                    sets=max(1, capacity // self._WAYS),
                                    ways=self._WAYS)

    # the legacy tests drive the replica clock directly (reader fence)
    @property
    def cts(self) -> int:
        return self.replica.cts

    @cts.setter
    def cts(self, v: int) -> None:
        self.replica.cts = int(v)

    def get(self, key: str):
        return self.replica.get(key)

    def put(self, key: str, value: Any) -> None:
        """Write-through: publish to the fabric, adopt its lease, and advance
        this replica's clock (cts = max(cts, wts))."""
        self.replica.put(key, value)

    @property
    def stats(self) -> Dict[str, int]:
        """Legacy counter names, derived from the replica's FabricStats."""
        s = self.replica.stats
        return {"hits": s.l1_hits,
                "coherence_misses": s.coh_miss_l1,
                "compulsory": s.compulsory,
                "refetches": s.refetches,
                "capacity_evictions": s.capacity_evictions}

    @property
    def fabric_stats(self):
        return self.replica.stats
