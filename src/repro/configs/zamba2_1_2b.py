"""zamba2-1.2b [hybrid] — Mamba2 backbone + one globally-shared attention
block applied every 6th layer. [arXiv:2411.15242]
38L d_model=2048 32H (kv=32) d_ff=8192 vocab=32000 ssm_state=64."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b", family="hybrid",
    n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32, d_head=64,
    d_ff=8192, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_groups=1, d_conv=4, expand=2,
    attn_every=6,
)
