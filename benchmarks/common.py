"""Shared benchmark helpers: timing, CSV rows, artifact caching."""
from __future__ import annotations

import json
import pathlib
import time
from typing import Callable, Dict, List

ART = pathlib.Path(__file__).resolve().parent / "artifacts"
ART.mkdir(parents=True, exist_ok=True)

_rows: List[str] = []


def emit(name: str, us_per_call: float, derived: str):
    row = f"{name},{us_per_call:.1f},{derived}"
    _rows.append(row)
    print(row, flush=True)


def rows() -> List[str]:
    return list(_rows)


def cached(name: str, fn: Callable[[], Dict], force: bool = False) -> Dict:
    """Run-once artifact cache so re-runs of the harness are cheap."""
    path = ART / f"{name}.json"
    if path.exists() and not force:
        return json.loads(path.read_text())
    out = fn()
    path.write_text(json.dumps(out, indent=1))
    return out


def timed(fn, *args) -> tuple:
    t0 = time.time()
    out = fn(*args)
    return out, (time.time() - t0) * 1e6
