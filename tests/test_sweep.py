"""Batched sweep engine vs sequential per-cell simulation (DESIGN.md §5).

The acceptance property of the figure engine: the [C, B] matrix produced by
ONE jitted ``engine.sweep`` equals running ``engine.simulate`` cell by cell
— across all five system structures (topology/policy/protocol branches),
across NOP trace padding, and across the stacked config-vmap axis."""
import numpy as np
import pytest

from repro.core import simulate, sweep, traces
from repro.core.sysconfig import (rdma_wb_hmg, rdma_wb_nc, sm_wb_nc,
                                  sm_wt_halcone, sm_wt_nc, stack_configs,
                                  static_key)

KW = dict(n_gpus=2, cus_per_gpu=4)
ROUNDS = 96
BENCHES = ("aes", "mm")


@pytest.fixture(scope="module")
def batch():
    base = sm_wt_halcone(**KW)
    tl = [traces.standard_trace(base, traces.STANDARD[b], ROUNDS)
          for b in BENCHES]
    # unequal lengths exercise pack_batch's NOP padding
    short = (tl[0][0][:, :ROUNDS - 17], tl[0][1][:, :ROUNDS - 17])
    tl = [short] + tl[1:]
    return tl, traces.pack_batch(tl)


def _assert_cell_parity(cfg, trace, cycles, counters, bi):
    r = simulate(cfg, *trace)
    np.testing.assert_allclose(cycles[bi], float(r["cycles"]),
                               rtol=1e-6, err_msg=cfg.name)
    for k, v in r["counters"].items():
        np.testing.assert_allclose(counters[k][bi], float(v), atol=1e-3,
                                   err_msg=f"{cfg.name}/{k}")


def test_sweep_matches_sequential_all_structures(batch):
    """All five modeled systems (five distinct static groups) in one jit."""
    tl, (ops_b, addrs_b) = batch
    cfgs = [f(**KW) for f in (rdma_wb_nc, rdma_wb_hmg, sm_wb_nc, sm_wt_nc,
                              sm_wt_halcone)]
    res = sweep(cfgs, ops_b, addrs_b)
    assert res["cycles"].shape == (len(cfgs), len(tl))
    for ci, cfg in enumerate(cfgs):
        for bi, trace in enumerate(tl):
            _assert_cell_parity(cfg, trace, res["cycles"][ci],
                                res["counters"]
                                and {k: v[ci] for k, v in
                                     res["counters"].items()}, bi)


def test_sweep_config_vmap_group(batch):
    """Lease variants share static structure -> one stacked vmap group."""
    tl, (ops_b, addrs_b) = batch
    cfgs = [sm_wt_halcone(rd_lease=rd, wr_lease=wr, **KW)
            for rd, wr in [(2, 10), (10, 2), (20, 5)]]
    assert len({static_key(c) for c in cfgs}) == 1
    stacked = stack_configs(cfgs)
    assert stacked.rd_lease.shape == (3,)
    res = sweep(cfgs, ops_b, addrs_b)
    for ci, cfg in enumerate(cfgs):
        for bi, trace in enumerate(tl):
            r = simulate(cfg, *trace)
            np.testing.assert_allclose(res["cycles"][ci, bi],
                                       float(r["cycles"]), rtol=1e-6)


def test_sweep_preserves_input_config_order(batch):
    """Grouping by static structure must not permute the result rows."""
    tl, (ops_b, addrs_b) = batch
    # interleave two structures so grouped execution differs from input order
    cfgs = [sm_wt_halcone(rd_lease=2, **KW), sm_wt_nc(**KW),
            sm_wt_halcone(rd_lease=30, **KW)]
    res = sweep(cfgs, ops_b, addrs_b)
    for ci, cfg in enumerate(cfgs):
        r = simulate(cfg, *tl[0])
        np.testing.assert_allclose(res["cycles"][ci, 0], float(r["cycles"]),
                                   rtol=1e-6, err_msg=f"row {ci}")


def test_pack_batch_padding_is_exact():
    """NOP padding adds no cycles, no counters."""
    base = sm_wt_halcone(**KW)
    ops, addrs = traces.standard_trace(base, traces.STANDARD["fir"], 48)
    padded_ops = np.pad(ops, ((0, 0), (0, 31)))
    padded_addrs = np.pad(addrs, ((0, 0), (0, 31)))
    a = simulate(base, ops, addrs)
    b = simulate(base, padded_ops, padded_addrs)
    np.testing.assert_allclose(float(a["cycles"]), float(b["cycles"]),
                               rtol=1e-7)
    for k in a["counters"]:
        np.testing.assert_allclose(float(a["counters"][k]),
                                   float(b["counters"][k]), atol=1e-3)


def test_fig10_byte_counters_decompose(batch):
    """The per-link byte counters are exactly state.link_bytes over the
    transaction counters — and HALCONE's inter-GPU bytes never contain an
    invalidation component (inval_msgs == 0, the Fig-10 claim)."""
    from repro.core.state import BLOCK_BYTES, CTRL_BYTES

    tl, _ = batch
    for cfg in (sm_wt_halcone(**KW), rdma_wb_hmg(**KW)):
        c = {k: float(v)
             for k, v in simulate(cfg, *tl[1])["counters"].items()}
        np.testing.assert_allclose(c["bytes_l1_l2"],
                                   c["l1_to_l2"] * BLOCK_BYTES)
        np.testing.assert_allclose(c["bytes_l2_mm"],
                                   c["l2_to_mm"] * BLOCK_BYTES)
        np.testing.assert_allclose(
            c["bytes_inter_gpu"],
            c["pcie_blocks"] * BLOCK_BYTES + c["inval_msgs"] * CTRL_BYTES)
        if cfg.protocol == "halcone":
            assert c["inval_msgs"] == 0.0


def test_stack_configs_rejects_mixed_structure():
    with pytest.raises(ValueError):
        stack_configs([sm_wt_halcone(**KW), sm_wt_nc(**KW)])


def test_simulate_res_log_block(batch):
    """The round step emits the packed per-op result block
    (core.state.RES_FIELDS, the same layout the fabric miss pass uses):
    read_log is exactly its version field masked to reads, found mirrors
    the memory ops, level only annotates reads, and mm_used implies a
    trip past both cache tiers (level == 3 wherever a read used MM)."""
    from repro.core.engine import READ, WRITE
    from repro.core.state import RES_FIELDS

    tl, _ = batch
    ops, addrs = tl[1]
    r = simulate(sm_wt_halcone(**KW), ops, addrs)
    fields = r["res_log"]
    assert tuple(fields) == RES_FIELDS
    for name in RES_FIELDS:
        assert fields[name].shape == ops.shape, name
    np.testing.assert_array_equal(
        np.asarray(r["read_log"]),
        np.where(ops == READ, fields["version"], -1))
    np.testing.assert_array_equal(
        fields["found"].astype(bool), (ops == READ) | (ops == WRITE))
    assert (fields["level"][ops != READ] == -1).all()
    read_levels = fields["level"][ops == READ]
    assert ((read_levels >= 0) & (read_levels <= 3)).all()
    mm_reads = (fields["mm_used"] == 1) & (ops == READ)
    assert (fields["level"][mm_reads] == 3).all()
    assert (fields["gseq"] == -1).all()      # no payload seq in the sim
    # leases only annotate memory ops
    assert (fields["rts"][(ops != READ) & (ops != WRITE)] == -1).all()
