"""Fig 10: per-link network traffic, invalidation vs data — the paper's
headline claim that timestamp self-invalidation ELIMINATES invalidation
traffic on the low-bandwidth inter-GPU links.

Driven by the batched sweep engine over the new per-link byte counters
(``core.state.link_bytes`` -> ``engine.COUNTERS``: bytes_l1_l2,
bytes_l2_mm, bytes_inter_gpu).  The HMG directory protocol pays
``CTRL_BYTES`` per invalidation message on the inter-GPU links
(``inval_msgs``); HALCONE's inter-GPU bytes decompose to pure data — the
invalidation component is zero BY CONSTRUCTION, which this script asserts
per cell, not just plots.

The same three counters are exported by the production fabric
(``FabricStats``; parity-pinned in tests/test_fabric_parity.py), so a
served trace decomposes row-for-row against these simulated bars.

Writes ``benchmarks/artifacts/fig10_traffic[_mini].json`` and (when
matplotlib is importable) ``benchmarks/artifacts/fig10_traffic.png``.
``mini=True`` is the CI footprint: 2 benchmarks at small ROUNDS.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks import common
from benchmarks.common import cached, emit
from repro.core import traces
from repro.core.state import CTRL_BYTES
from repro.core.sysconfig import rdma_wb_hmg, sm_wt_halcone

ROUNDS = 2048
GEOM = dict(pcie_lat=1000.0)       # same geometry as the Fig 7 sweep
CONFIGS = [
    ("RDMA-WB-C-HMG", rdma_wb_hmg),        # directory: invalidations flow
    ("SM-WT-C-HALCONE", sm_wt_halcone),    # timestamps: none can
]
LINKS = ("bytes_l1_l2", "bytes_l2_mm", "bytes_inter_gpu")
# The in-place benchmarks update READ-WRITE SHARED data (the accesses
# that actually need coherence).  fws/bs now carry their calibrated
# rw_share in traces.STANDARD itself (ISSUE 5 satellite: the Fig-7/8/9
# speedup sweeps exercise write-sharing coherence misses too); THIS
# figure additionally enables bfs's irregular shared-frontier updates —
# a traffic-split-only extra, too noisy for the speedup calibration.
RW_SHARE = {"bfs": 0.05}
MINI_BENCHES = ["bs", "fws"]
MINI_ROUNDS = 256


def _bench(name: str) -> traces.BenchModel:
    m = traces.STANDARD[name]
    return dataclasses.replace(m, rw_share=RW_SHARE.get(name, m.rw_share))


def run_all(force: bool = False, mini: bool = False):
    benches = MINI_BENCHES if mini else list(traces.STANDARD)
    rounds = MINI_ROUNDS if mini else ROUNDS

    def compute():
        base = sm_wt_halcone(**GEOM)
        named = {b: traces.standard_trace(base, _bench(b), rounds)
                 for b in benches}
        return common.sweep([(n, mk(**GEOM)) for n, mk in CONFIGS], named,
                            measure_sequential=False)

    name = "fig10_traffic_mini" if mini else "fig10_traffic"
    return cached(name, compute, force, script=__file__)


def decompose(data) -> dict:
    """Per (config, benchmark): the three per-link byte totals, with the
    inter-GPU bytes split into invalidation vs data components."""
    cnames, bnames = data["configs"], data["benchmarks"]
    ctr = data["counters"]
    out = {"configs": cnames, "benchmarks": bnames, "links": {}}
    for link in LINKS:
        out["links"][link] = [[float(ctr[link][ci][bi])
                               for bi in range(len(bnames))]
                              for ci in range(len(cnames))]
    inval = [[float(ctr["inval_msgs"][ci][bi]) * CTRL_BYTES
              for bi in range(len(bnames))] for ci in range(len(cnames))]
    out["inter_gpu_inval_bytes"] = inval
    out["inter_gpu_data_bytes"] = [
        [out["links"]["bytes_inter_gpu"][ci][bi] - inval[ci][bi]
         for bi in range(len(bnames))] for ci in range(len(cnames))]
    return out


def _plot(dec, path) -> bool:
    try:
        import matplotlib
        matplotlib.use("Agg")
        import matplotlib.pyplot as plt
    except ImportError:
        return False
    cnames, bnames = dec["configs"], dec["benchmarks"]
    x = np.arange(len(bnames), dtype=float)
    width = 0.8 / len(cnames)
    fig, axes = plt.subplots(1, 2, figsize=(11, 4))
    for ci, cname in enumerate(cnames):
        off = (ci - (len(cnames) - 1) / 2) * width
        data_b = np.asarray(dec["inter_gpu_data_bytes"][ci])
        inval_b = np.asarray(dec["inter_gpu_inval_bytes"][ci])
        axes[0].bar(x + off, data_b, width, label=f"{cname} data")
        axes[0].bar(x + off, inval_b, width, bottom=data_b,
                    label=f"{cname} inval", hatch="//")
        axes[1].bar(x + off, np.asarray(dec["links"]["bytes_l2_mm"][ci]),
                    width, label=cname)
    axes[0].set_title("inter-GPU link bytes (data vs invalidation)")
    axes[1].set_title("L2<->MM link bytes")
    for ax in axes:
        ax.set_xticks(x, bnames, rotation=45, fontsize=7)
        ax.legend(fontsize=6)
    fig.tight_layout()
    fig.savefig(path, dpi=120)
    plt.close(fig)
    return True


def main(force: bool = False, mini: bool = False):
    data = run_all(force, mini)
    dec = decompose(data)
    cnames, bnames = dec["configs"], dec["benchmarks"]
    hc = cnames.index("SM-WT-C-HALCONE")
    hmg = cnames.index("RDMA-WB-C-HMG")
    # the claim itself, asserted per cell: no invalidation byte ever
    # travels in HALCONE, while HMG pays them on every shared write
    assert all(v == 0.0 for v in dec["inter_gpu_inval_bytes"][hc]), \
        "HALCONE produced invalidation traffic — the protocol is broken"
    total_hmg_inval = sum(dec["inter_gpu_inval_bytes"][hmg])
    for bi, b in enumerate(bnames):
        emit(f"fig10/{b}/inter_gpu", 0.0,
             f"hmg_data={dec['inter_gpu_data_bytes'][hmg][bi]:.0f}B;"
             f"hmg_inval={dec['inter_gpu_inval_bytes'][hmg][bi]:.0f}B;"
             f"halcone_data={dec['inter_gpu_data_bytes'][hc][bi]:.0f}B;"
             f"halcone_inval=0B")
    emit("fig10/claim", 0.0,
         f"halcone_inval_bytes=0;hmg_inval_bytes={total_hmg_inval:.0f};"
         f"claim={'OK' if total_hmg_inval > 0 else 'HMG-SILENT'}")
    png = common.ART / "fig10_traffic.png"
    if not mini and _plot(dec, png):
        emit("fig10/plot", 0.0, f"png={png.name}")
    return dec


if __name__ == "__main__":
    main()
