"""Graph-colored conflict rounds: properties and colored-vs-greedy parity.

ISSUE 8's tentpole replaces the greedy contiguous round splitters with
order-preserving chain-depth graph coloring (``pipeline.color_rounds``)
so a set-colliding storm needs `max conflict-chain depth` rounds instead
of `number of contiguous conflict-free segments`.  Three properties keep
the passes exact and worth it:

  * **order preservation** — any two ops sharing a resource (key,
    replica set, shared set, TSU shard) land in strictly increasing
    rounds in op order, so committing rounds in order IS the sequential
    order along every conflict chain;
  * **never worse than greedy** — the colored splitter uses at most as
    many rounds as the PR-5/PR-6 contiguous splitters (kept as oracles:
    ``conflict_rounds_greedy`` / ``write_rounds_greedy``), and strictly
    fewer on interleaved storms (the round-budget fallback fires less);
  * **pass parity** — the miss / write passes produce bit-identical
    results, stats, grant logs and device state whether driven by the
    colored or the greedy rounds (randomized storms, both splitters over
    the same fabric geometry).
"""
import numpy as np
import pytest

from repro.coherence.fabric import (ArrayFabric, FabricConfig, HostFabric,
                                    Op)
from repro.coherence.fabric import pipeline as P_

# tight sets so random storms collide constantly (deep conflict chains)
TIGHT = dict(n_shards=2, rd_lease=8, wr_lease=4, tsu_capacity=16,
             shared_sets=4, shared_ways=2, replica_sets=2, replica_ways=2,
             max_in_flight=3)


def _random_ops(rng, n, nk=12):
    """Random op footprints shaped like interned keys: the set/shard
    routes are functions of the key id, as ``ArrayFabric._kid`` makes
    them."""
    kids = rng.integers(0, nk, n).astype(np.int64)
    return kids, (kids * 7 + 3) % 4, (kids * 5 + 1) % 8, kids % 2


def _check_rounds(rounds, n):
    """Structural invariants shared by every splitter: the rounds are a
    partition of range(n), ascending within each round."""
    cat = np.concatenate([r for r in rounds]) if n else np.asarray([])
    assert sorted(cat.tolist()) == list(range(n))
    for r in rounds:
        assert list(r) == sorted(r)


# ---------------------------------------------------------- color_rounds
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_color_rounds_order_preserving_within_chains(seed):
    rng = np.random.default_rng(seed)
    kids, s1, s2, _ = _random_ops(rng, 64)
    fps = [((0, k), (1, a), (2, b)) for k, a, b in zip(kids, s1, s2)]
    colors = P_.color_rounds(fps)
    for i in range(len(fps)):
        for j in range(i + 1, len(fps)):
            if set(fps[i]) & set(fps[j]):
                assert colors[i] < colors[j], (i, j, colors)


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_colored_read_rounds_never_more_than_greedy(seed):
    rng = np.random.default_rng(seed)
    kids, s1, s2, _ = _random_ops(rng, 48)
    colored = P_.conflict_rounds(kids, s1, s2)
    greedy = P_.conflict_rounds_greedy(kids, s1, s2)
    _check_rounds(colored, len(kids))
    _check_rounds(greedy, len(kids))
    assert len(colored) <= len(greedy)


def test_colored_reads_beat_greedy_on_interleaved_storm():
    """The motivating case: two interleaved conflict chains.  Greedy
    breaks at every repeat (one round per op pair); coloring packs each
    chain level into one round — chain depth rounds total."""
    kids = np.asarray([0, 1] * 8)             # a,b,a,b,... (16 ops)
    s1 = kids % 2
    s2 = kids % 4
    colored = P_.conflict_rounds(kids, s1, s2)
    greedy = P_.conflict_rounds_greedy(kids, s1, s2)
    assert len(greedy) == 8                   # one break per (a, b) pair
    assert len(colored) == 8                  # chains are depth 8 here
    # phase-offset duplicate pairs: every chain is depth 2, but greedy's
    # contiguous breaks straddle the pairs — n_keys + 1 segments
    kids = np.asarray([0, 0, 1, 1, 2, 2, 3, 3])
    s1 = (kids * 7 + 3) % 8
    s2 = (kids * 5) % 8
    colored = P_.conflict_rounds(kids, s1, s2)
    greedy = P_.conflict_rounds_greedy(kids, s1, s2)
    assert len(greedy) == 5
    assert len(colored) == 2


# -------------------------------------------------------- write schedule
@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_write_schedule_colored_matches_greedy_drains(seed):
    """The drain schedule is lane-static — identical under both
    splitters — and the colored rounds never outnumber the greedy ones
    while preserving op order along every hard-resource chain."""
    rng = np.random.default_rng(seed)
    kids, s1, s2, shard = _random_ops(rng, 40)
    pending = [(int(k), int(a), int(b), int(sh), 1, -1)
               for k, a, b, sh in zip(*_random_ops(rng, 2))]
    args = (kids, s1, s2, shard, 1, -1, pending, 3)
    colored, sc = P_.write_schedule(*args)
    greedy, sg = P_.write_rounds_greedy(*args)
    np.testing.assert_array_equal(sc, sg)      # schedule is round-free
    _check_rounds(colored, len(kids))
    _check_rounds(greedy, len(kids))
    assert len(colored) <= len(greedy)
    # order preservation over the hard footprints (push + non-exempt
    # drain resources), colors strictly increase along each chain
    colors = np.zeros(len(kids), np.int64)
    for r, idxs in enumerate(colored):
        colors[idxs] = r
    last: dict = {}
    for j in range(len(kids)):
        fp = [("k", int(kids[j])), ("s1", 1, int(s1[j]))]
        if sc[0, j]:
            fp += [("sh", int(sc[4, j])), ("s2", int(sc[6, j]))]
        for res in fp:
            if res in last:
                assert colors[j] >= colors[last[res]], (j, res)
            last[res] = j


# ------------------------------------------------------------ pass parity
def _drive_read_storms(fab, seed, n_calls=8):
    """Publish-seeded random read storms with heavy key duplication (deep
    conflict chains in the miss subset)."""
    rng = np.random.default_rng(seed)
    keys = [f"c{i}" for i in range(10)]
    out = [fab.apply([Op("publish", k, f"{k}@0", node=i % 2)
                      for i, k in enumerate(keys)])]
    for c in range(n_calls):
        batch = [keys[int(i)] for i in rng.integers(0, len(keys), 24)]
        rep = int(rng.integers(4))
        out.append([("rb", fab.read_batch(batch, replica=rep))])
        if c % 3 == 2:
            fab.write_batch([(keys[int(i)], f"w{c}.{i}")
                             for i in rng.integers(0, len(keys), 6)],
                            replica=rep)
            out.append([("fence", fab.fence())])
    return out


def _drive_write_storms(fab, seed, n_calls=8):
    rng = np.random.default_rng(seed)
    keys = [f"w{i}" for i in range(8)]
    out = []
    for c in range(n_calls):
        items = [(keys[int(i)], f"v{c}.{j}")
                 for j, i in enumerate(rng.integers(0, len(keys), 16))]
        rep = int(rng.integers(4))
        wl = (None, 2, 9)[int(rng.integers(3))]
        fab.write_batch(items, replica=rep, wr_lease=wl)
        if c % 2:
            out.append(("fence", fab.fence()))
        out.append(("reads", fab.read_batch(keys, replica=rep)))
    return out


def _assert_same_fabric(a, b):
    import jax

    assert list(a.grant_log) == list(b.grant_log)
    assert a.stats() == b.stats()
    for r in range(a.n_replicas):
        assert a.replica_stats(r) == b.replica_stats(r)
    for x, y in zip(jax.tree_util.tree_leaves(a._af),
                    jax.tree_util.tree_leaves(b._af)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_read_pass_colored_vs_greedy_parity(seed, monkeypatch):
    """The miss pass is bit-identical under colored and greedy rounds —
    results, grant log, stats, mirrors and the full device state — and
    both match the host oracle."""
    cfg = FabricConfig(**TIGHT)
    mk = lambda: ArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    colored = mk()
    out_c = _drive_read_storms(colored, seed)
    monkeypatch.setattr(P_, "conflict_rounds", P_.conflict_rounds_greedy)
    greedy = mk()
    out_g = _drive_read_storms(greedy, seed)
    host = HostFabric(cfg, n_nodes=2, replicas_per_node=2)
    out_h = _drive_read_storms(host, seed)
    for c, g in zip(out_c, out_g):
        assert [r for _, r in c] == [r for _, r in g]
    for c, h in zip(out_c, out_h):
        assert [r for _, r in c] == [r for _, r in h]
    assert list(colored.grant_log) == list(host.grant_log)
    assert colored.stats() == host.stats()
    _assert_same_fabric(colored, greedy)


@pytest.mark.parametrize("seed", [3, 4, 5])
def test_write_pass_colored_vs_greedy_parity(seed, monkeypatch):
    """The lane-static write pass (and the fences between storms) is
    bit-identical under colored and greedy rounds, and both match the
    host oracle."""
    cfg = FabricConfig(**TIGHT)
    mk = lambda: ArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    colored = mk()
    out_c = _drive_write_storms(colored, seed)
    orig = P_.write_schedule
    monkeypatch.setattr(P_, "write_schedule",
                        lambda *a: orig(*a, splitter="greedy"))
    greedy = mk()
    out_g = _drive_write_storms(greedy, seed)
    monkeypatch.undo()
    host = HostFabric(cfg, n_nodes=2, replicas_per_node=2)
    out_h = _drive_write_storms(host, seed)
    assert out_c == out_g == out_h
    assert list(colored.grant_log) == list(host.grant_log)
    assert colored.stats() == host.stats()
    _assert_same_fabric(colored, greedy)


try:
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=25, deadline=None)
    @given(st.lists(st.integers(0, 9), min_size=1, max_size=40),
           st.integers(0, 5))
    def test_fuzz_colored_rounds_properties(kid_list, nset):
        """Hypothesis sweep of the two structural properties on read
        rounds: partition-of-range + order preservation + <= greedy."""
        kids = np.asarray(kid_list, np.int64)
        s1 = (kids + nset) % 3
        s2 = (kids * 3 + nset) % 5
        colored = P_.conflict_rounds(kids, s1, s2)
        greedy = P_.conflict_rounds_greedy(kids, s1, s2)
        _check_rounds(colored, len(kids))
        assert len(colored) <= len(greedy)
        colors = np.zeros(len(kids), np.int64)
        for r, idxs in enumerate(colored):
            colors[idxs] = r
        for i in range(len(kids)):
            for j in range(i + 1, len(kids)):
                if kids[i] == kids[j] or s1[i] == s1[j] or s2[i] == s2[j]:
                    assert colors[i] < colors[j]
except ImportError:                                   # pragma: no cover
    pass
