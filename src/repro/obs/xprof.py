"""Static cost probes: jaxpr collective accounting + compiled cost analysis.

Runtime spans (``obs.trace``) answer *where wall-clock went*; this module
answers *what the compiled program structurally does* — before it runs:

  * ``jaxpr_collectives(jaxpr)`` — walk a (closed) jaxpr, including every
    nested sub-jaxpr (scan/while/cond/pjit bodies), and count collective
    primitives: total occurrences, how many sit inside a loop body (those
    execute once PER ITERATION — the O(ops)-collectives failure mode the
    batched grant pipeline removes), and a per-primitive breakdown.  This
    is the generalization of ``pipeline.collective_counts`` (which now
    delegates here; the parity suite's O(1)-per-batch pin is unchanged).
  * ``cost_probe(fn, *args)`` — lower+compile a jittable and report XLA's
    cost analysis (FLOPs, bytes accessed) alongside the jaxpr collective
    counts, as one JSON-able dict.  Recorded next to the runtime rows in
    BENCH_fabric.json so a perf regression can be split into "the program
    got bigger" vs "the program got slower".

Everything here is trace/compile time only — nothing is imported on the
fabric hot path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax

__all__ = ["COLLECTIVE_PRIMS", "LOOP_PRIMS", "jaxpr_collectives",
           "cost_probe"]

COLLECTIVE_PRIMS = ("all_gather", "all_to_all", "psum", "ppermute",
                    "reduce_scatter")
LOOP_PRIMS = ("scan", "while")


def _sub_jaxprs(v):
    if hasattr(v, "eqns"):                     # a Jaxpr
        yield v
    elif hasattr(v, "jaxpr"):                  # a ClosedJaxpr
        yield v.jaxpr
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _sub_jaxprs(x)


def jaxpr_collectives(jaxpr) -> Dict[str, Any]:
    """Count collective primitives in a (closed) jaxpr.

    Returns ``{"total", "in_loop", "by_primitive": {name: count},
    "loops"}`` where ``in_loop`` counts collectives inside a scan/while
    body (executed once per iteration) and ``loops`` is the number of
    loop bodies encountered.  A collective's *per-batch* execution count
    is ``total - in_loop + in_loop * iterations``."""
    counts: Dict[str, Any] = {"total": 0, "in_loop": 0, "loops": 0,
                              "by_primitive": {}}

    def walk(jx, in_loop):
        for eqn in jx.eqns:
            name = eqn.primitive.name
            if any(c in name for c in COLLECTIVE_PRIMS):
                counts["total"] += 1
                counts["by_primitive"][name] = \
                    counts["by_primitive"].get(name, 0) + 1
                if in_loop:
                    counts["in_loop"] += 1
            is_loop = any(l in name for l in LOOP_PRIMS)
            if is_loop:
                counts["loops"] += 1
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    walk(sub, in_loop or is_loop)

    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr, False)
    return counts


def _cost_analysis_dict(compiled) -> Optional[Dict[str, float]]:
    """Normalize ``Compiled.cost_analysis()`` across jax versions: it has
    returned a dict, a list of one dict per device, or None (backends
    without HLO cost analysis)."""
    try:
        ca = compiled.cost_analysis()
    except Exception:                          # pragma: no cover - backend
        return None
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return dict(ca) if isinstance(ca, dict) else None


def cost_probe(fn, *args, donate_argnums=(), **kwargs) -> Dict[str, Any]:
    """Lower + compile ``fn(*args, **kwargs)`` and report its static cost.

    ``fn`` may be a plain function or an already-jitted callable (both
    expose ``.lower`` after wrapping).  Returns::

        {"flops": float|None, "bytes_accessed": float|None,
         "collectives": jaxpr_collectives(...),
         "output_bytes": float|None}

    FLOPs/bytes come from XLA's compiled cost analysis and are ``None``
    when the backend doesn't expose them; the collective counts always
    come from the traced jaxpr (backend-independent).
    """
    jitted = fn if hasattr(fn, "lower") else jax.jit(
        fn, donate_argnums=donate_argnums)
    # make_jaxpr traces through jitted callables too (the pjit eqn's body
    # is walked as a sub-jaxpr), so one path serves both input kinds
    coll = jaxpr_collectives(jax.make_jaxpr(fn)(*args, **kwargs))
    compiled = jitted.lower(*args, **kwargs).compile()
    ca = _cost_analysis_dict(compiled)
    flops = bytes_accessed = out_bytes = None
    if ca:
        flops = ca.get("flops")
        out_bytes = ca.get("bytes accessed output")
        # XLA reports per-operand keys 'bytes accessed operand N {}' plus a
        # total 'bytes accessed'; prefer the total
        bytes_accessed = ca.get("bytes accessed")
    return {"flops": flops, "bytes_accessed": bytes_accessed,
            "output_bytes": out_bytes, "collectives": coll}
