"""Batched serving runtime with the lease-coherent prefix cache.

Requests are grouped into fixed-size decode batches; shared prompt prefixes
hit the LeaseKVCache (HALCONE semantics: reuse without revalidation while the
lease is live).  All leases come from the coherence fabric — pass a shared
``TSUFabric`` to run many Server replicas against one sharded TSU service.
Single-process reference implementation of the multi-replica serving
pattern; launch/serve.py drives it on the production mesh.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.coherence.fabric import TSUFabric
from repro.coherence.kv_lease import AuthoritativeStore, LeaseKVCache
from repro.models import decode_step, init_cache, prefill
from repro.sharding import NOSHARD


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int = 8


def _prefix_key(tokens: np.ndarray) -> str:
    return hashlib.sha1(tokens.tobytes()).hexdigest()[:16]


class Server:
    def __init__(self, cfg, params, *, batch_size: int = 4,
                 max_len: int = 128, store: Optional[AuthoritativeStore] = None,
                 fabric: Optional[TSUFabric] = None, node_id: int = 0):
        self.cfg, self.params = cfg, params
        self.B, self.max_len = batch_size, max_len
        store = store or AuthoritativeStore(fabric=fabric, node_id=node_id)
        self.fabric = store.fabric
        self.kv = LeaseKVCache(store)
        self._prefill = jax.jit(
            lambda p, c, t: prefill(cfg, p, t, c, ctx=NOSHARD))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos, ctx=NOSHARD))

    def _prefill_batch(self, prompts: np.ndarray):
        """Prefix-cached prefill: identical prompt batches reuse cached KV."""
        key = _prefix_key(prompts)
        hit = self.kv.get(key)
        if hit is not None:
            cache, first = hit[0]
            return cache, first
        cache = init_cache(self.cfg, prompts.shape[0], self.max_len)
        first, cache = self._prefill(self.params, cache,
                                     jnp.asarray(prompts))
        self.kv.put(key, (cache, first))
        return cache, first

    def serve(self, requests: List[Request]) -> Dict[int, np.ndarray]:
        out: Dict[int, np.ndarray] = {}
        for i in range(0, len(requests), self.B):
            group = requests[i:i + self.B]
            while len(group) < self.B:                 # pad the last batch
                group.append(Request(rid=-1, prompt=group[0].prompt))
            prompts = np.stack([g.prompt for g in group])
            S = prompts.shape[1]
            cache, nxt = self._prefill_batch(prompts)
            toks = [np.asarray(nxt)]
            max_new = max(g.max_new for g in group)
            for t in range(max_new - 1):
                nxt, cache = self._decode(self.params, cache, nxt[:, None],
                                          jnp.int32(S + t))
                toks.append(np.asarray(nxt))
            gen = np.stack(toks, 1)                    # [B, max_new]
            for j, g in enumerate(group):
                if g.rid >= 0:
                    out[g.rid] = gen[j, :g.max_new]
        return out

    @property
    def cache_stats(self):
        return dict(self.kv.stats)

    @property
    def fabric_stats(self):
        """Fabric-wide telemetry (engine.COUNTERS names + service extras)."""
        return self.fabric.stats.to_dict()
