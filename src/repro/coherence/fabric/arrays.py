"""Array-native coherence fabric: the whole TSU service as device arrays.

This is the production implementation of the ``FabricBackend`` contract
(backend.py).  All coherence state lives in ``core.state`` pytrees:

  * sharded TSU+MM   — a ``[n_shards, capacity]`` table (``TSUState`` with
    one fully-associative set per shard) plus version / allocation-order /
    write-sequence side arrays,
  * replica tier     — ``TierState`` ``[n_replicas, sets, ways+1]``,
  * node-shared tier — ``TierState`` ``[n_nodes, sets, ways+1]``,
  * write queue      — a bounded ring per node, drained in-scan,

and a batch of ops is applied as ONE jitted ``lax.scan`` (``apply``): each
step dispatches on the op kind and runs the same transition sequence the
host objects execute per key — probe, self-invalidate on expiry, descend,
TSU grant (16-bit overflow reinit included), install back up — with every
lease decision served by ``core.state`` (→ ``core.protocol`` + the Pallas
lease-probe kernel).  No timestamp rule is implemented here: this file is
routing, gating and bookkeeping over the shared transition layer.

Values (the actual cached payloads — KV blocks, parameter blobs) stay on
the host: every MM write is stamped with a globally unique write sequence
number (``gseq``) carried alongside each cached line, and the wrapper maps
``gseq -> value``.  The arrays decide *everything* (hits, grants, versions,
evictions); the host only moves payloads per the returned plan.

Bit-identical to ``HostFabric`` on any op trace — grants, hit levels,
versions, and the full ``FabricStats`` block (tests/test_fabric_parity.py,
DESIGN.md §7).
"""
from __future__ import annotations

import collections
import functools
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.coherence.fabric import pipeline as P_
from repro.coherence.fabric.backend import (GRANT_LOG_LEN, FabricBackend,
                                            Op, ReadBatchHandle, _bounded)
from repro.coherence.fabric.stats import GI as _GI
from repro.coherence.fabric.stats import G_KEYS as _G_KEYS
from repro.coherence.fabric.stats import RI as _RI
from repro.coherence.fabric.stats import R_KEYS as _R_KEYS
from repro.coherence.fabric.tsu import FabricConfig, stable_hash
from repro.core import protocol
from repro.core import state as S
from repro.core.state import TSUState, TierState
from repro.obs import trace as obs
from repro.sharding import named_sharding, shard_map

_NOP, _READ, _WRITE, _FENCE, _MM_WRITE, _PUBLISH, _MM_READ = range(7)
_PRUNE_EVERY = 4096          # payload-map GC cadence, in completed writes
_KIND = {"read": _READ, "write": _WRITE, "fence": _FENCE,
         "mm_write": _MM_WRITE, "publish": _PUBLISH, "mm_read": _MM_READ}

# pipelines: "batched" = one packed grant collective per batch + the
# vectorized miss pass; "scan" = the PR-4 per-op collective schedule,
# kept for ordering-sensitive debugging (DESIGN.md §9)
PIPELINES = ("batched", "scan")
# read_batch falls back to the op-scan when the miss subset needs more
# conflict-free rounds than max(_MIN_ROUND_BUDGET, m // 4): one pass round
# costs a few scan steps of dispatch, so the pipeline stops paying off
# when conflicts (duplicate keys / set collisions) shred the subset into
# near-sequential rounds.  A deduplicated serving batch is 1-2 rounds.
_MIN_ROUND_BUDGET = 6


class _AF(NamedTuple):
    """The device-resident fabric state."""

    rp: TierState            # replica tier [R, S1, W1+1]
    rp_gseq: jnp.ndarray     # write-sequence id per line (payload handle)
    rp_tick: jnp.ndarray     # [R] LRU tick (host _SetAssoc._tick semantics)
    sh: TierState            # shared tier [Nn, S2, W2+1]
    sh_gseq: jnp.ndarray
    sh_tick: jnp.ndarray     # [Nn]
    tsu: TSUState            # [Ks, 1, cap+1]
    tsu_ver: jnp.ndarray     # per-entry version (resets on realloc)
    tsu_gseq: jnp.ndarray
    tsu_seq: jnp.ndarray     # allocation order (victim tie-break)
    tsu_nseq: jnp.ndarray    # [Ks] next allocation seq
    gseq_next: jnp.ndarray   # global write-sequence counter
    wq: Dict[str, jnp.ndarray]   # ring fields [Nn, Q]
    wq_head: jnp.ndarray     # [Nn]
    wq_len: jnp.ndarray      # [Nn]
    g: jnp.ndarray           # global counters [len(_G_KEYS)]
    r: jnp.ndarray           # per-replica counters [R, len(_R_KEYS)]


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p <<= 1
    return p


def _af_pspecs() -> _AF:
    """The fabric state's mesh layout as a ``PartitionSpec`` prefix tree:
    the TSU table and its per-shard sequencers (version / gseq / alloc-seq
    side arrays, next-seq counters) live along the ``fabric`` axis — shard
    rows ``[d*KS/D, (d+1)*KS/D)`` on device ``d`` — while the client tiers,
    write-queue rings and counters are replicated (every device derives
    the identical update from replicated op inputs + broadcast grants)."""
    F, R = P("fabric"), P()
    return _AF(rp=R, rp_gseq=R, rp_tick=R, sh=R, sh_gseq=R, sh_tick=R,
               tsu=F, tsu_ver=F, tsu_gseq=F, tsu_seq=F, tsu_nseq=F,
               gseq_next=R, wq=R, wq_head=R, wq_len=R, g=R, r=R)


@functools.lru_cache(maxsize=32)
def _build_run(S1s, W1, S2s, W2, KS, CAP, NN, NR, Q, MAXIF, LD, MESH=None,
               PIPE="batched"):
    """The jitted op-scan for one static geometry.  Cached so every
    ArrayFabric instance with the same shape shares one compilation.

    With ``MESH`` (a 1-axis ``fabric`` mesh) the scan becomes a
    ``repro.sharding.shard_map`` body: the TSU table and its per-shard
    sequencers are laid out along the mesh axis (each device owns
    ``KS / D`` contiguous shards — the paper's one-TSU-per-HBM-stack
    placement).  Client tiers, write-queue rings and counters stay
    replicated: they are updated by identical arithmetic on every device
    (all op inputs and exchanged grants are replicated), so the sharded
    scan is bit-identical to the single-device one.  What travels over
    the fabric axis depends on ``PIPE`` (DESIGN.md §9):

      * ``"scan"``   — the PR-4 schedule: every op's TSU transition
        executes only on its key's owning device and the packed grant
        (wts/rts/version + counter flags) hops back as ONE ``all_gather``
        per scan step — O(ops) collectives per batch.  The rare-op
        ``lax.cond`` gates are replaced by masked execution so each
        device runs the same symmetric collective sequence.  Kept for
        ordering-sensitive debugging.
      * ``"batched"`` — the batched grant pipeline never builds a meshed
        op-scan at all: each device's owned TSU rows (tag/memts/ver/gseq/
        seq/nseq packed into ONE contiguous buffer, ``state.pack_tsu``)
        are exchanged ONCE per batch (``state.owner_gather``, the
        dedicated ``_build_tsu_gather`` program), and the collective-free
        MESH=None programs — this op-scan and the miss/write/fence
        passes — run on the lead device against the assembled table
        (``ArrayFabric._xin``/``_xout``, DESIGN.md §12a).  O(1)
        collectives per batch, one compilation shared with the
        single-device fabric.
    """
    i32 = jnp.int32
    one = jnp.ones((), i32)
    zero = jnp.zeros((), i32)
    NG, NRK = len(_G_KEYS), len(_R_KEYS)
    b2i = lambda b: b.astype(i32)

    sharded = MESH is not None and PIPE == "scan"   # per-op collectives?
    D = int(MESH.devices.size) if MESH is not None else 1
    SPD = KS // D                    # shards per device (divisibility checked
                                     # by the caller)
    if sharded:
        def shard_ctx(shard):
            """Route a (global) home-shard id: the device-local row, an
            am-I-the-owner mask, and the owning device's axis index."""
            me = jax.lax.axis_index("fabric").astype(i32)
            owner = shard // SPD
            lsh = jnp.clip(shard - me * SPD, 0, SPD - 1)
            return lsh, owner == me, owner

        def bcast(owner, *vals):
            """The cross-shard hop: the owner's scalars travel over the
            fabric axis (all_gather), everyone selects the owner's row."""
            rows = jax.lax.all_gather(jnp.stack(vals), "fabric")   # [D, n]
            row = rows[owner]
            return tuple(row[i] for i in range(len(vals)))
    else:
        def shard_ctx(shard):
            return shard, jnp.ones((), bool), zero

        def bcast(owner, *vals):
            return vals

    def gv(**kw):
        """One [NG] increment vector — a single add per counter block."""
        out = jnp.zeros((NG,), i32)
        return out.at[jnp.array([_GI[k] for k in kw], i32)].add(
            jnp.stack([b2i(v) if v.dtype == bool else v
                       for v in kw.values()]))

    def rv(**kw):
        out = jnp.zeros((NRK,), i32)
        return out.at[jnp.array([_RI[k] for k in kw], i32)].add(
            jnp.stack([b2i(v) if v.dtype == bool else v
                       for v in kw.values()]))

    def probe1(tier, idx, st, key, mwts, mrts):
        out = S.tier_probe(tier, idx[None], st[None], key[None],
                           mwts[None], mrts[None])
        return tuple(o[0] for o in out)

    def touch(tier, tick, idx, st, key, active):
        """Host probe semantics: on a tag match, bump the store tick and
        refresh the line's LRU (even if the lease is dead)."""
        th, hit, way, _, _, _, _ = probe1(tier, idx, st, key, zero, zero)
        th, hit = th & active, hit & active
        tick2 = tick.at[idx].add(b2i(th))
        w = jnp.where(th, way, tier.n_ways)
        lru2 = tier.lru.at[idx, st, w].set(
            jnp.where(th, tick2[idx], tier.lru[idx, st, w]))
        return tier._replace(lru=lru2), tick2, th, hit, way

    def drop(tier, idx, st, way, cond):
        w = jnp.where(cond, way, tier.n_ways)
        return tier._replace(tag=tier.tag.at[idx, st, w].set(
            jnp.where(cond, S.INVALID, tier.tag[idx, st, w])))

    def install_at(tier, gseq_a, tick, idx, st, key, wts, rts, ver, gs,
                   th, way, active):
        """Host install semantics with the same-key probe precomputed:
        tick++, in-place on ``(th, way)``, else the victim way (invalid
        first, then LRU); reports displacement of a live different-key
        line (a capacity eviction)."""
        vic = S.victim(tier.tag, tier.lru, idx[None], st[None])[0]
        w0 = jnp.where(th, way, vic)
        evicted = active & ~th & (tier.tag[idx, st, w0] != S.INVALID)
        tick2 = tick.at[idx].add(b2i(active))
        w = jnp.where(active, w0, tier.n_ways)

        def pt(a, v):
            return a.at[idx, st, w].set(jnp.where(active, v, a[idx, st, w]))

        tier2 = TierState(tag=pt(tier.tag, key), wts=pt(tier.wts, wts),
                          rts=pt(tier.rts, rts), ver=pt(tier.ver, ver),
                          lru=pt(tier.lru, tick2[idx]), cts=tier.cts)
        return tier2, pt(gseq_a, gs), tick2, evicted

    F = jnp.zeros((), bool)

    def fill(tier, gseq_a, tick, idx, st, key, wts, rts, ver, gs, active):
        """A fill after a miss: the key cannot be present (an expired line
        was already dropped), so the install always takes the victim way."""
        return install_at(tier, gseq_a, tick, idx, st, key, wts, rts, ver,
                          gs, F, zero, active)

    def tsu_probe(af, shard, key):
        th, way = S.probe(af.tsu.tag, shard[None], zero[None], key[None])
        return th[0], way[0]

    def mm_write1(af, key, shard, wl, rd, wr, active):
        """TSUShard.mm_write: allocate (evicting the min-(memts, alloc-seq)
        entry when the shard is full), grant via Algorithm 3 + overflow
        reinit, bump the version.  Sharded: the transition executes on the
        owning device only; the grant travels back via ``bcast``."""
        lsh, mine, owner = shard_ctx(shard)
        local = active & mine
        th, way = tsu_probe(af, lsh, key)
        vic = S.victim_lex(af.tsu.tag, af.tsu.memts, af.tsu_seq,
                           lsh[None], zero[None])[0]
        full = (af.tsu.tag[lsh, 0][:CAP] != S.INVALID).all()
        evict = local & ~th & full
        w0 = jnp.where(th, way, vic)
        memts = jnp.where(th, af.tsu.memts[lsh, 0, w0], 0)
        wl_eff = jnp.where(wl >= 0, wl, wr)
        gr = S.tsu_lease(memts[None], jnp.ones((1,), bool), rd, wl_eff[None])
        mwts, mrts, nmem, ovf = (gr.wts[0], gr.rts[0], gr.new_memts[0],
                                 gr.overflow[0])
        ver = jnp.where(th, af.tsu_ver[lsh, 0, w0] + 1, 1)
        seqv = jnp.where(th, af.tsu_seq[lsh, 0, w0], af.tsu_nseq[lsh])
        gs = af.gseq_next
        tsu2 = S.tsu_commit_exact(af.tsu, lsh[None], zero[None], w0[None],
                                  key[None], nmem[None], local[None])
        w = jnp.where(local, w0, CAP)

        def pt(a, v):
            return a.at[lsh, 0, w].set(jnp.where(local, v, a[lsh, 0, w]))

        # the grant + counter flags hop from the owning shard's device
        mwts_b, mrts_b, ver_b, evict_i, ovf_i = bcast(
            owner, mwts, mrts, ver, b2i(evict), b2i(active & ovf))
        af = af._replace(
            tsu=tsu2, tsu_ver=pt(af.tsu_ver, ver),
            tsu_gseq=pt(af.tsu_gseq, gs), tsu_seq=pt(af.tsu_seq, seqv),
            tsu_nseq=af.tsu_nseq.at[lsh].add(b2i(local & ~th)),
            gseq_next=af.gseq_next + b2i(active),
            g=af.g + gv(tsu_evictions=evict_i, overflow_reinits=ovf_i))
        return af, mwts_b, mrts_b, ver_b, gs

    def mm_read1(af, key, shard, rd, wr, active):
        """TSUShard.mm_read: grant only if the entry exists (sharded: on the
        owning device; found/grant/version hop back via ``bcast``)."""
        lsh, mine, owner = shard_ctx(shard)
        th, way = tsu_probe(af, lsh, key)
        local_found = active & mine & th
        memts = jnp.where(th, af.tsu.memts[lsh, 0, way], 0)
        gr = S.tsu_lease(memts[None], jnp.zeros((1,), bool), rd, wr)
        mwts, mrts, nmem, ovf = (gr.wts[0], gr.rts[0], gr.new_memts[0],
                                 gr.overflow[0])
        tsu2 = S.tsu_commit_exact(af.tsu, lsh[None], zero[None],
                                  way[None], key[None], nmem[None],
                                  local_found[None])
        ver = af.tsu_ver[lsh, 0, way]
        gs = af.tsu_gseq[lsh, 0, way]
        th_i, mwts, mrts, ver, gs, ovf_i = bcast(
            owner, b2i(th), mwts, mrts, ver, gs, b2i(ovf))
        found = active & (th_i > 0)
        af = af._replace(tsu=tsu2,
                         g=af.g + gv(overflow_reinits=b2i(found) * ovf_i))
        return af, found, mwts, mrts, jnp.where(found, ver, -1), \
            jnp.where(found, gs, -1)

    def drain1(af, node, rd, wr, active):
        """WriteQueue._drain_one: pop the oldest posted write, write through
        to the TSU, adopt the grant into the node tier, then install the
        ADOPTED lease into the submitting replica (the engine's L2-then-L1
        response chain)."""
        h = af.wq_head[node]
        key = af.wq["key"][node, h]
        rep = af.wq["rep"][node, h]
        wl = af.wq["wl"][node, h]
        shard = af.wq["shard"][node, h]
        s1 = af.wq["set1"][node, h]
        s2 = af.wq["set2"][node, h]
        cross = active & (shard != node % KS)
        _, b2m, big = S.link_bytes(zero, b2i(active), b2i(cross))
        af = af._replace(
            wq_head=af.wq_head.at[node].set(jnp.where(active, (h + 1) % Q, h)),
            wq_len=af.wq_len.at[node].add(-b2i(active)),
            g=af.g + gv(l2_to_mm=active, write_throughs=active,
                        pcie_blocks=cross, bytes_l2_mm=b2m,
                        bytes_inter_gpu=big))
        af, mwts, mrts, ver, gs = mm_write1(af, key, shard, wl, rd, wr,
                                            active)
        # adopt into the node-shared tier (grant lease, node clock advance)
        thA, _, wayA, _, nwA, nrA, ncA = probe1(af.sh, node, s2, key,
                                                mwts, mrts)
        af = af._replace(sh=af.sh._replace(cts=af.sh.cts.at[node].set(
            jnp.where(active, ncA, af.sh.cts[node]))))
        sh2, shg2, sht2, ev1 = install_at(af.sh, af.sh_gseq, af.sh_tick,
                                          node, s2, key, nwA, nrA, ver, gs,
                                          thA, wayA, active)
        # install the adopted lease into the submitting replica
        thB, _, wayB, _, nwB, nrB, ncB = probe1(af.rp, rep, s1, key,
                                                nwA, nrA)
        af = af._replace(
            sh=sh2, sh_gseq=shg2, sh_tick=sht2,
            rp=af.rp._replace(cts=af.rp.cts.at[rep].set(
                jnp.where(active, ncB, af.rp.cts[rep]))),
            r=af.r.at[rep].add(rv(write_throughs=active)))
        rp2, rpg2, rpt2, ev2 = install_at(af.rp, af.rp_gseq, af.rp_tick,
                                          rep, s1, key, nwB, nrB, ver, gs,
                                          thB, wayB, active)
        af = af._replace(
            rp=rp2, rp_gseq=rpg2, rp_tick=rpt2,
            g=af.g + gv(capacity_evictions=b2i(ev1) + b2i(ev2)),
            r=af.r.at[rep].add(rv(capacity_evictions=ev2)))
        entry = (jnp.where(active, key, -1), ver, mwts, mrts, gs)
        return af, entry

    def _flush_node(carry, node, rd, wr, gate=None):
        def cond(c):
            go = c[0].wq_len[node] > 0
            return go if gate is None else go & gate

        def body(c):
            af_, dk, dv, dw, dr_, dg, dc = c
            af_, e = drain1(af_, node, rd, wr, jnp.bool_(True))
            return (af_, dk.at[dc].set(e[0]), dv.at[dc].set(e[1]),
                    dw.at[dc].set(e[2]), dr_.at[dc].set(e[3]),
                    dg.at[dc].set(e[4]), dc + 1)

        return jax.lax.while_loop(cond, body, carry)

    def run(af, xs, rd, wr):
        ldz = jnp.full((LD,), -1, i32)
        negs = jnp.full((), -1, i32)

        def step(af, x):
            kind, rep, node, key, s1, s2, shard, wl = (
                x["kind"], x["rep"], x["node"], x["key"], x["set1"],
                x["set2"], x["shard"], x["wl"])
            is_read = kind == _READ
            is_write = kind == _WRITE
            is_fence = kind == _FENCE
            is_mmw = kind == _MM_WRITE
            is_pub = kind == _PUBLISH
            is_mmr = kind == _MM_READ
            home_miss = shard != node % KS

            # ---- replica probe: serves the read lookup AND the posted
            # write's pending-line placement (ReplicaCache.get / .put)
            rp2, rpt2, th1, h1, way1 = touch(af.rp, af.rp_tick, rep, s1,
                                             key, is_read)
            af = af._replace(rp=rp2, rp_tick=rpt2)
            hit_ver = af.rp.ver[rep, s1, way1]
            hit_gs = af.rp_gseq[rep, s1, way1]
            miss = is_read & ~h1
            coh = miss & th1
            comp = miss & ~th1
            af = af._replace(rp=drop(af.rp, rep, s1, way1, coh))
            # pending line (store-buffer forwarding): wts=rts=cts, ver=-1
            thP, _, wayP, _, _, _, _ = probe1(af.rp, rep, s1, key,
                                              zero, zero)
            cts = af.rp.cts[rep]
            rpP, rpgP, rptP, evP = install_at(
                af.rp, af.rp_gseq, af.rp_tick, rep, s1, key, cts, cts,
                negs, negs, thP, wayP, is_write)
            af = af._replace(rp=rpP, rp_gseq=rpgP, rp_tick=rptP)

            # ---- shared probe (SharedCache.get, only on a replica miss)
            sh2, sht2, th2, h2, way2 = touch(af.sh, af.sh_tick, node, s2,
                                             key, miss)
            af = af._replace(sh=sh2, sh_tick=sht2)
            sh_ver = af.sh.ver[node, s2, way2]
            sh_gs = af.sh_gseq[node, s2, way2]
            sh_wts = af.sh.wts[node, s2, way2]
            sh_rts = af.sh.rts[node, s2, way2]
            coh2 = miss & th2 & ~h2
            af = af._replace(sh=drop(af.sh, node, s2, way2, coh2))

            # ---- MM/TSU access (fabric.read for misses + raw mm_read;
            # mm_write/publish behind a cond — rare on the serving path)
            need_mm = miss & ~h2
            af, fndR, mwtsR, mrtsR, mverR, mgsR = mm_read1(
                af, key, shard, rd, wr, need_mm | is_mmr)
            do_mmw = is_mmw | is_pub

            def _mmw(af):
                return mm_write1(af, key, shard, wl, rd, wr,
                                 jnp.ones((), bool))

            def _mmw_skip(af):
                return af, zero, zero, zero, zero

            if sharded:
                # masked, not cond-gated: every device must execute the
                # same symmetric collective sequence
                af, mwtsW, mrtsW, mverW, mgsW = mm_write1(
                    af, key, shard, wl, rd, wr, do_mmw)
            else:
                af, mwtsW, mrtsW, mverW, mgsW = jax.lax.cond(
                    do_mmw, _mmw, _mmw_skip, af)
            mm_used = (need_mm & fndR) | is_mmr & fndR | do_mmw
            mwts = jnp.where(do_mmw, mwtsW, mwtsR)
            mrts = jnp.where(do_mmw, mrtsW, mrtsR)
            mver = jnp.where(do_mmw, mverW, mverR)
            mgs = jnp.where(do_mmw, mgsW, mgsR)

            # ---- shared-tier install: the read fill (always a victim way
            # — the expired line was dropped) and the publish adopt share
            # one probe+install-math call
            thA, _, wayA, _, nwA, nrA, ncA = probe1(af.sh, node, s2, key,
                                                    mwts, mrts)
            af = af._replace(sh=af.sh._replace(cts=af.sh.cts.at[node].set(
                jnp.where(is_pub, ncA, af.sh.cts[node]))))
            fill_sh = (need_mm & fndR) | is_pub
            sh3, shg3, sht3, evF = install_at(af.sh, af.sh_gseq, af.sh_tick,
                                              node, s2, key, nwA, nrA,
                                              mver, mgs, thA, wayA, fill_sh)
            af = af._replace(sh=sh3, sh_gseq=shg3, sh_tick=sht3)

            # ---- response travelling up to the replica (read path)
            fndF = need_mm & fndR
            resp_found = h2 | fndF
            resp_ver = jnp.where(h2, sh_ver, mver)
            resp_gs = jnp.where(h2, sh_gs, mgs)
            resp_wts = jnp.where(h2, sh_wts, nwA)
            resp_rts = jnp.where(h2, sh_rts, nrA)
            nw1, nr1, _ = S.install_lease(af.rp.cts[rep], resp_wts,
                                          resp_rts)
            rp3, rpg3, rpt3, ev1 = fill(af.rp, af.rp_gseq, af.rp_tick,
                                        rep, s1, key, nw1, nr1,
                                        resp_ver, resp_gs, resp_found)
            af = af._replace(rp=rp3, rp_gseq=rpg3, rp_tick=rpt3)

            # ---- posted write-through: ring push + bounded drain
            t = (af.wq_head[node] + af.wq_len[node]) % Q
            vals = {"key": key, "rep": rep, "wl": wl, "shard": shard,
                    "set1": s1, "set2": s2}
            wq2 = {k: a.at[node, t].set(
                jnp.where(is_write, vals[k], a[node, t]))
                for k, a in af.wq.items()}
            af = af._replace(wq=wq2,
                             wq_len=af.wq_len.at[node].add(b2i(is_write)))
            need_drain = is_write & (af.wq_len[node] > MAXIF)

            def _dr(af):
                return drain1(af, node, rd, wr, jnp.ones((), bool))

            def _dr_skip(af):
                return af, (negs, negs, negs, negs, negs)

            if sharded:
                af, e = drain1(af, node, rd, wr, need_drain)
            else:
                af, e = jax.lax.cond(need_drain, _dr, _dr_skip, af)
            dk = ldz.at[0].set(e[0])
            dv = ldz.at[0].set(e[1])
            dw = ldz.at[0].set(e[2])
            dr_ = ldz.at[0].set(e[3])
            dg = ldz.at[0].set(e[4])
            dc = b2i(need_drain)

            # ---- fence: flush every queue (node order), clocks jump to
            # the global max (rare -> behind a cond; sharded: gated
            # while-loops so the collective schedule stays symmetric)
            def _fence(af):
                carry = (af, ldz, ldz, ldz, ldz, ldz, zero)
                for nd in range(NN):
                    carry = _flush_node(carry, jnp.int32(nd), rd, wr)
                af, fk, fv, fw, fr_, fg, fc = carry
                gmax = jnp.maximum(jnp.max(af.rp.cts), jnp.max(af.sh.cts))
                af = af._replace(
                    rp=af.rp._replace(cts=jnp.full_like(af.rp.cts, gmax)),
                    sh=af.sh._replace(cts=jnp.full_like(af.sh.cts, gmax)))
                return af, (fk, fv, fw, fr_, fg, fc, gmax)

            def _fence_skip(af):
                return af, (dk, dv, dw, dr_, dg, dc, zero)

            if sharded:
                # a fence op is never a write, so (dk..dc) are still the
                # empty drain log here; the gated flush leaves them
                # untouched on non-fence ops (zero loop trips everywhere)
                carry = (af, dk, dv, dw, dr_, dg, dc)
                for nd in range(NN):
                    carry = _flush_node(carry, jnp.int32(nd), rd, wr,
                                        gate=is_fence)
                af, dk, dv, dw, dr_, dg, dc = carry
                gmax_all = jnp.maximum(jnp.max(af.rp.cts),
                                       jnp.max(af.sh.cts))
                gmax = jnp.where(is_fence, gmax_all, zero)
                af = af._replace(
                    rp=af.rp._replace(cts=jnp.where(
                        is_fence, jnp.full_like(af.rp.cts, gmax_all),
                        af.rp.cts)),
                    sh=af.sh._replace(cts=jnp.where(
                        is_fence, jnp.full_like(af.sh.cts, gmax_all),
                        af.sh.cts)))
            else:
                af, (dk, dv, dw, dr_, dg, dc, gmax) = jax.lax.cond(
                    is_fence, _fence, _fence_skip, af)

            # ---- counters: one vector add per block
            b12, b2m, big = S.link_bytes(
                b2i(miss) + b2i(is_write),
                b2i(need_mm) + b2i(is_mmr) + b2i(do_mmw),
                b2i(need_mm & home_miss))
            af = af._replace(
                g=af.g + gv(
                    reads=is_read, writes=is_write, l1_hits=h1, l2_hits=h2,
                    l1_to_l2=b2i(miss) + b2i(is_write), coh_miss_l1=coh,
                    coh_miss_l2=coh2,
                    self_invalidations=b2i(coh) + b2i(coh2),
                    compulsory=comp,
                    l2_to_mm=b2i(need_mm) + b2i(is_mmr) + b2i(do_mmw),
                    pcie_blocks=need_mm & home_miss,
                    write_throughs=do_mmw, fences=is_fence,
                    refetches=resp_found,
                    capacity_evictions=b2i(evP) + b2i(evF) + b2i(ev1),
                    bytes_l1_l2=b12, bytes_l2_mm=b2m, bytes_inter_gpu=big),
                r=af.r.at[rep].add(rv(
                    reads=is_read, writes=is_write, l1_hits=h1, l2_hits=h2,
                    l1_to_l2=b2i(miss) + b2i(is_write), coh_miss_l1=coh,
                    coh_miss_l2=coh2,
                    self_invalidations=b2i(coh) + b2i(coh2),
                    compulsory=comp, refetches=resp_found,
                    # a publish adopt's eviction hits fabric stats only
                    capacity_evictions=(b2i(evP) + b2i(evF & fndF)
                                        + b2i(ev1)))))

            # ---- per-op result record
            found = (is_read & (h1 | resp_found)) | (mm_used & ~is_fence)
            version = jnp.where(
                is_read, jnp.where(h1, hit_ver,
                                   jnp.where(resp_found, resp_ver, -1)),
                jnp.where(mm_used, mver, -1))
            gseq = jnp.where(
                is_read, jnp.where(h1, hit_gs,
                                   jnp.where(resp_found, resp_gs, -1)),
                jnp.where(mm_used, mgs, -1))
            level = jnp.where(
                ~is_read, -1,
                jnp.where(h1, 0, jnp.where(h2, 1, jnp.where(fndF, 2, 3))))
            res = dict(found=b2i(found), version=version, gseq=gseq,
                       level=level, wts=jnp.where(mm_used, mwts, 0),
                       rts=jnp.where(mm_used, mrts, 0),
                       mm_used=b2i(mm_used), gmax=gmax, dlog_key=dk,
                       dlog_ver=dv, dlog_wts=dw, dlog_rts=dr_, dlog_gseq=dg,
                       dcount=dc)
            return af, res

        return jax.lax.scan(step, af, xs)

    if MESH is None:
        # the fabric state is donated: callers always rebind it to the
        # returned carry, and aliasing lets XLA update the tier/TSU
        # arrays in place across batches.  The batched pipeline's sharded
        # engine ALSO lands here: it assembles the full TSU on the lead
        # device with the ONE-collective gather program
        # (``_build_tsu_gather``) and runs this collective-free program
        # against the assembled state (DESIGN.md §12a).
        return jax.jit(run, donate_argnums=0)
    af_spec = _af_pspecs()
    # per-op collective schedule (PIPE="scan"): the TSU-side state is
    # partitioned along the fabric axis, everything else replicated;
    # the per-op results come back replicated (identical on every
    # device by construction)
    return jax.jit(shard_map(run, MESH,
                             in_specs=(af_spec, P(), P(), P()),
                             out_specs=(af_spec, P()), check_vma=False),
                   donate_argnums=0)


@functools.lru_cache(maxsize=8)
def _build_fast_read(mesh=None):
    """Phase 1 of the two-phase batched read (backend.read_batch contract):
    ONE vectorized ``state.tier_probe`` over the whole batch serves every
    replica-tier lease hit — reads under a live lease are pure local
    arithmetic, the paper's serving claim — with sequential touch
    semantics (op i's LRU = tick + its rank among the batch's hits).
    Misses are untouched here; the caller runs them through the exact
    op-scan in op order (phase 2).  Only the replica-tier sub-state flows
    through the call, keeping dispatch overhead off the hot path.

    With ``mesh`` the probe runs as a ``shard_map`` body over the fabric
    axis with fully replicated operands: a lease hit is shard-LOCAL by
    definition (the paper's serving claim — no TSU, no collective, zero
    inter-GPU bytes), so the body contains no communication at all and
    its outputs stay replicated."""
    i32 = jnp.int32

    def fast(rp, rp_gseq, rp_tick, g, r, meta_s1, kids, rep):
        B = kids.shape[0]
        z = jnp.zeros((B,), i32)
        reps = jnp.full((B,), rep, i32)
        s1s = meta_s1[kids]
        th, hit, way, _, _, _, _ = S.tier_probe(rp, reps, s1s, kids, z, z)
        hi = hit.astype(i32)
        rank = jnp.cumsum(hi)            # hit rank (single replica per call)
        w = jnp.where(hit, way, rp.n_ways)
        # .max == sequential .set here: lru values are past ticks, and a
        # duplicate key's later touch carries the larger rank
        lru2 = rp.lru.at[reps, s1s, w].max(rp_tick[rep] + rank)
        ver = rp.ver[reps, s1s, way]
        gseq = rp_gseq[reps, s1s, way]
        # single replica per call -> every counter update is one scalar op
        nh = jnp.sum(hi)
        tick2 = rp_tick.at[rep].add(nh)
        g2 = g.at[_GI["reads"]].add(nh).at[_GI["l1_hits"]].add(nh)
        r2 = r.at[rep, _RI["reads"]].add(nh)
        r2 = r2.at[rep, _RI["l1_hits"]].add(nh)
        # only the MODIFIED arrays travel back — the untouched tier fields
        # stay resident — and the per-op outputs are packed into one
        # transfer, keeping the hot-path call payload minimal
        return jnp.stack([hi, ver, gseq]), lru2, tick2, g2, r2

    if mesh is None:
        return jax.jit(fast)
    return jax.jit(shard_map(fast, mesh, in_specs=(P(),) * 8,
                             out_specs=(P(),) * 5, check_vma=False))


@functools.lru_cache(maxsize=32)
def _build_miss_run(W1, W2, KS):
    """Phase 2 of the two-phase batched read, jitted: the vectorized miss
    pass (``pipeline.make_miss_pass``) — ALL conflict-free rounds of the
    miss subset in one call (one ``lax.scan`` over the round masks, the
    fabric state donated so XLA updates it in place), one batched probe
    per tier, ONE batched TSU grant and one batched fill per tier per
    round.  The program is collective-free; the sharded engine brackets
    it with the gather/scatter exchange (``ArrayFabric._xin``/``_xout``),
    so a miss-heavy sharded serving batch costs O(1) collectives no
    matter how many rounds or misses."""
    return jax.jit(P_.make_miss_pass(W1, W2, KS), donate_argnums=0)


@functools.lru_cache(maxsize=32)
def _build_write_run(W1, W2, KS, NN, NR, Q, MAXIF):
    """The batched write pass, jitted: ALL conflict-free rounds of a
    posted-write batch in one call (``pipeline.make_write_pass`` — one
    ``lax.scan`` over the round masks, the fabric state donated), the
    lane-static drain schedule resolved on the host
    (``pipeline.write_schedule``), ONE batched TSU write-through grant
    per round (``state.tsu_commit_write_batch``) and prefix-sum
    clock/LRU sequencing (DESIGN.md §11).  Collective-free; the sharded
    engine brackets it with the gather/scatter exchange, so a republish
    storm costs O(1) collectives no matter how many writes or rounds."""
    return jax.jit(P_.make_write_pass(W1, W2, KS, NN, NR, Q, MAXIF),
                   donate_argnums=0)


@functools.lru_cache(maxsize=32)
def _build_fence_run(W1, W2, KS, NN, NR, Q):
    """The vectorized fence pass, jitted (``pipeline.make_fence_pass``):
    drain EVERY node's queue over conflict-free rounds with the
    lane-static schedule from ``pipeline.fence_schedule``, then jump all
    client clocks to the global max (§11b).  Collective-free; used by the
    sharded batched engine so the serving loop's fences stop paying the
    op-scan's per-op dispatch (the single-device ``ArrayFabric`` keeps
    the op-scan fence as the reference path)."""
    return jax.jit(P_.make_fence_pass(W1, W2, KS, NN, NR, Q),
                   donate_argnums=0)


@functools.lru_cache(maxsize=8)
def _build_tsu_gather(MESH):
    """The batched engine's per-batch grant exchange, jitted: pack each
    device's owned TSU rows (``state.pack_tsu``) and assemble the full
    shard-major buffer on every device with ONE ``owner_gather`` — the
    batch's single collective — returning the unpacked full-table leaves
    (replicated; the engine adopts the lead device's copy).  This is the
    one program the O(1)-collectives-per-batch pin traces for the dev0
    pass engine: the passes themselves are collective-free."""
    F = P("fabric")

    def body(tsu, ver, gseq, seq, nseq):
        return S.unpack_tsu(S.owner_gather(
            S.pack_tsu(tsu, ver, gseq, seq, nseq), "fabric"))

    return jax.jit(shard_map(body, MESH, in_specs=(F,) * 5,
                             out_specs=(P(),) * 5, check_vma=False))


class ArrayFabric(FabricBackend):
    """The array-native fabric: ``FabricBackend`` over one jitted op-scan.

    ``apply(ops)`` encodes the batch into int32 op arrays (keys are interned
    to dense ids; set indexes and shard routes precomputed with the same
    ``stable_hash`` the host stores use), runs the scan, then replays the
    returned plan on the host-side payload map.  Batches are padded to
    power-of-two lengths so compilations are reused across batch sizes.
    """

    def __init__(self, cfg: FabricConfig = FabricConfig(),
                 n_nodes: int = 1, replicas_per_node: int = 1, mesh=None,
                 pipeline: str = "batched"):
        self.cfg = cfg = _bounded(cfg)
        if pipeline not in PIPELINES:
            raise ValueError(f"pipeline must be one of {PIPELINES}, "
                             f"got {pipeline!r}")
        self.pipeline = pipeline
        self.n_nodes = n_nodes
        self.n_replicas = n_nodes * replicas_per_node
        self._rpn = replicas_per_node
        self._S1 = max(1, cfg.replica_sets)
        self._W1 = max(1, cfg.replica_ways)
        self._S2 = max(1, cfg.shared_sets)
        self._W2 = max(1, cfg.shared_ways)
        self._KS = cfg.n_shards
        self._CAP = cfg.tsu_capacity
        self._Q = cfg.max_in_flight + 2
        self._LD = n_nodes * cfg.max_in_flight + 1
        self.mesh = mesh                 # 1-axis "fabric" mesh or None
        if mesh is not None and self._KS % int(mesh.devices.size):
            raise ValueError(
                f"n_shards={self._KS} must be divisible by the fabric "
                f"mesh's {int(mesh.devices.size)} devices")
        # the batched pipeline runs every program on the lead device
        # against gather-assembled state (the dev0 pass engine, DESIGN.md
        # §12a), so its op-scan / passes are the collective-free MESH=None
        # programs — shared compilations with the single-device fabric.
        # Only pipeline="scan" keeps the per-op shard_map schedule.
        run_mesh = mesh if (mesh is not None and pipeline == "scan") \
            else None
        self._run = _build_run(self._S1, self._W1, self._S2, self._W2,
                               self._KS, self._CAP, n_nodes,
                               self.n_replicas, self._Q, cfg.max_in_flight,
                               self._LD, run_mesh, "scan")
        self._miss_run = (_build_miss_run(self._W1, self._W2, self._KS)
                          if pipeline == "batched" else None)
        self._write_run = (_build_write_run(self._W1, self._W2, self._KS,
                                            n_nodes, self.n_replicas,
                                            self._Q, cfg.max_in_flight)
                           if pipeline == "batched" else None)
        self._fence_run = (_build_fence_run(self._W1, self._W2, self._KS,
                                            n_nodes, self.n_replicas,
                                            self._Q)
                           if pipeline == "batched" else None)
        # the sharded batched engine: ONE packed owner_gather per batch
        # assembles the full TSU table, the passes run on the lead device,
        # and `_xout` scatters the updated TSU rows back to their owners
        # then immediately dispatches the NEXT batch's gather — the
        # exchange double-buffers under the current batch's host decode
        # (ISSUE 8 tentpole, DESIGN.md §12a)
        if mesh is not None and pipeline == "batched":
            self._gather_run = _build_tsu_gather(mesh)
            self._dev0 = jax.devices()[0]
            f3 = named_sharding(mesh, (self._KS, 1, self._CAP + 1),
                                ("fabric_shard", None, None))
            f1 = named_sharding(mesh, (self._KS,), ("fabric_shard",))
            self._tsu_shardings = (f3, f3, f3, f3, f1)
        else:
            self._gather_run = None
        self._tsu_full = None
        self._af = self._init_af()
        # host-side payload plumbing (the arrays decide; this only ships)
        self._keys: Dict = {}
        self._key_list: List = []
        self._meta = np.zeros((64, 3), np.int32)    # kid -> set1, set2, shard
        self._vals: Dict[int, object] = {}          # gseq -> value
        self._pending: Dict[Tuple[int, int], object] = {}
        self._pending_n: Dict[Tuple[int, int], int] = {}   # in-flight count
        self._qmirror = [collections.deque() for _ in range(n_nodes)]
        # bounded on BOTH backends with the same cap, so parity-compared
        # logs truncate identically (oracle traces are far shorter)
        self.grant_log = collections.deque(maxlen=GRANT_LOG_LEN)
        self._fast_read = _build_fast_read(run_mesh)
        self._meta_dev = None           # device-side kid -> set1 table
        self._fast_read_batches = 0     # all-hit batches (FabricStats field)
        self._write_batches = 0         # non-empty write_batch calls
        self._writes_since_prune = 0

    def _init_af(self) -> _AF:
        i32 = jnp.int32
        z = lambda *s: jnp.zeros(s, i32)
        neg = lambda *s: jnp.full(s, -1, i32)
        Nn, R = self.n_nodes, self.n_replicas
        af = _AF(
            rp=S.init_tier(R, self._S1, self._W1),
            rp_gseq=neg(R, self._S1, self._W1 + 1), rp_tick=z(R),
            sh=S.init_tier(Nn, self._S2, self._W2),
            sh_gseq=neg(Nn, self._S2, self._W2 + 1), sh_tick=z(Nn),
            tsu=S.init_tsu(self._KS, 1, self._CAP),
            tsu_ver=z(self._KS, 1, self._CAP + 1),
            tsu_gseq=neg(self._KS, 1, self._CAP + 1),
            tsu_seq=z(self._KS, 1, self._CAP + 1), tsu_nseq=z(self._KS),
            gseq_next=jnp.zeros((), i32),
            wq={k: z(Nn, self._Q) for k in
                ("key", "rep", "wl", "shard", "set1", "set2")},
            wq_head=z(Nn), wq_len=z(Nn),
            g=z(len(_G_KEYS)), r=z(R, len(_R_KEYS)),
        )
        if self.mesh is not None:
            f3 = named_sharding(self.mesh, (self._KS, 1, self._CAP + 1),
                                ("fabric_shard", None, None))
            f1 = named_sharding(self.mesh, (self._KS,), ("fabric_shard",))
            if self.pipeline == "batched":
                # dev0 pass engine: only the TSU — the state of record the
                # per-batch gather assembles — lives on the mesh; every
                # other leaf stays on the lead device where the passes run
                af = af._replace(
                    tsu=jax.device_put(af.tsu, f3),
                    tsu_ver=jax.device_put(af.tsu_ver, f3),
                    tsu_gseq=jax.device_put(af.tsu_gseq, f3),
                    tsu_seq=jax.device_put(af.tsu_seq, f3),
                    tsu_nseq=jax.device_put(af.tsu_nseq, f1))
            else:
                # per-op schedule: lay the state out per _af_pspecs BEFORE
                # the first run — TSU rows land on their owning devices
                # (sharding.py rules map the shard-major dims onto the
                # fabric axis), the rest replicated
                rep = NamedSharding(self.mesh, P())
                af = jax.device_put(af, _AF(
                    rp=rep, rp_gseq=rep, rp_tick=rep, sh=rep, sh_gseq=rep,
                    sh_tick=rep, tsu=f3, tsu_ver=f3, tsu_gseq=f3,
                    tsu_seq=f3, tsu_nseq=f1, gseq_next=rep, wq=rep,
                    wq_head=rep, wq_len=rep, g=rep, r=rep))
        return af

    # --------------------------------------------------- grant exchange
    def _dispatch_gather(self) -> None:
        af = self._af
        self._tsu_full = self._gather_run(af.tsu, af.tsu_ver,
                                          af.tsu_gseq, af.tsu_seq,
                                          af.tsu_nseq)

    def _xin(self) -> _AF:
        """Enter a device pass: hand it the lead-device view of the
        fabric state.  On the sharded batched engine the TSU leaves are
        the gather-assembled full table — prefetched by the previous
        ``_xout`` (dispatched here only on the very first batch) and
        adopted as zero-copy lead-device views of the replicated gather
        outputs.  Identity on the single-device fabric."""
        if self._gather_run is None:
            return self._af
        if self._tsu_full is None:
            self._dispatch_gather()
        full = self._tsu_full
        self._tsu_full = None
        dev0 = self._dev0

        def local(x):
            for s in x.addressable_shards:
                if s.device == dev0:
                    return s.data
            return jax.device_put(x, dev0)

        tsu, ver, gseq, seq, nseq = jax.tree_util.tree_map(local, full)
        return self._af._replace(tsu=tsu, tsu_ver=ver, tsu_gseq=gseq,
                                 tsu_seq=seq, tsu_nseq=nseq)

    def _xout(self, af: _AF) -> None:
        """Leave a device pass: adopt its output state.  On the sharded
        batched engine the updated TSU rows scatter back to their owning
        devices (async) and the NEXT batch's gather is dispatched
        immediately, so the one collective per batch overlaps this
        batch's host-side decode instead of sitting on the critical
        path."""
        if self._gather_run is None:
            self._af = af
            return
        tsu, ver, gseq, seq, nseq = jax.device_put(
            (af.tsu, af.tsu_ver, af.tsu_gseq, af.tsu_seq, af.tsu_nseq),
            self._tsu_shardings)
        self._af = af._replace(tsu=tsu, tsu_ver=ver, tsu_gseq=gseq,
                               tsu_seq=seq, tsu_nseq=nseq)
        self._dispatch_gather()

    # ------------------------------------------------------------- keys
    def _kid(self, key) -> int:
        kid = self._keys.get(key)
        if kid is None:
            kid = len(self._key_list)
            self._keys[key] = kid
            self._key_list.append(key)
            if kid >= self._meta.shape[0]:
                self._meta = np.concatenate(
                    [self._meta, np.zeros_like(self._meta)], axis=0)
            h = stable_hash(key)
            self._meta[kid] = (h % self._S1, h % self._S2, h % self._KS)
            self._meta_dev = None        # device copy is stale
        return kid

    # ------------------------------------------------------------ apply
    def apply(self, ops: Sequence[Op]):
        B0 = len(ops)
        if B0 == 0:
            return []
        B = max(8, _next_pow2(B0))
        with obs.span("fabric.pack", n_ops=B0, padded=B):
            enc = {k: np.zeros((B,), np.int32) for k in
                   ("kind", "rep", "node", "key", "set1", "set2", "shard",
                    "wl")}
            for i, op in enumerate(ops):
                enc["kind"][i] = _KIND[op.kind]
                if op.kind == "fence":
                    continue
                kid = self._kid(op.key)
                s1, s2, shard = self._meta[kid]
                rep = op.replica
                node = (op.node if op.kind == "publish"
                        else rep // self._rpn)
                enc["rep"][i] = rep
                enc["node"][i] = node
                enc["key"][i] = kid
                enc["set1"][i] = s1
                enc["set2"][i] = s2
                enc["shard"][i] = shard
                enc["wl"][i] = -1 if op.wr_lease is None else op.wr_lease
        with obs.span("fabric.exchange"):
            xs = {k: jnp.asarray(v) for k, v in enc.items()}
            af = self._xin()
        with obs.span("fabric.scan", n_ops=B0):
            af, res = self._run(af, xs,
                                jnp.int32(self.cfg.rd_lease),
                                jnp.int32(self.cfg.wr_lease))
            self._xout(af)
            obs.fence(res, "fabric.scan.device")
        with obs.span("fabric.decode", n_ops=B0):
            res = jax.device_get(res)
            out = [(op, self._decode(op, res, i))
                   for i, op in enumerate(ops)]
        if self._writes_since_prune >= _PRUNE_EVERY:
            with obs.span("fabric.donate"):
                self.prune_payloads()   # after decode: results already out
        return out

    def prune_payloads(self) -> None:
        """Drop payload versions no longer referenced by any device-side
        line or TSU entry.  HostFabric sheds values implicitly when a dict
        entry / cache line is evicted; here payloads are named by gseq
        handles, so an explicit sweep against the live handle set keeps
        host memory bounded on long-running serving paths."""
        live = set()
        for a in (self._af.rp_gseq, self._af.sh_gseq, self._af.tsu_gseq):
            live.update(np.unique(np.asarray(a)).tolist())
        self._vals = {g: v for g, v in self._vals.items() if g in live}
        self._writes_since_prune = 0

    def _drains(self, res, i, node: Optional[int] = None) -> None:
        """Replay the op's drain log on the payload map + grant log.  A
        write op drains its own node's queue; a fence drains every queue in
        node order (node=None -> pop the first non-empty mirror)."""
        for j in range(int(res["dcount"][i])):
            dk = int(res["dlog_key"][i][j])
            nd = (node if node is not None else
                  next(n for n in range(self.n_nodes) if self._qmirror[n]))
            mk, mval, mrep, _mwl = self._qmirror[nd].popleft()
            assert mk == dk, "queue mirror diverged from the in-scan ring"
            self._vals[int(res["dlog_gseq"][i][j])] = mval
            self._writes_since_prune += 1
            # last in-flight write for (rep, key) drained: the replica line
            # now carries a real gseq, so the store-buffer copy can go
            n = self._pending_n.get((mrep, mk), 0) - 1
            if n <= 0:
                self._pending_n.pop((mrep, mk), None)
                self._pending.pop((mrep, mk), None)
            else:
                self._pending_n[(mrep, mk)] = n
            self.grant_log.append((self._key_list[dk],
                                   int(res["dlog_wts"][i][j]),
                                   int(res["dlog_rts"][i][j]),
                                   int(res["dlog_ver"][i][j])))

    def _read_result(self, kid: int, replica: int, found, version, gseq):
        """Decode one read op's device outputs into the API result: None
        on a miss, store-buffer forwarding (version < 0) of a posted
        write, else payload + version.  The ONE read-decode shared by the
        op-scan path and the batched miss pass (the phase-1 hit loop
        inlines the same rule for throughput)."""
        if not found:
            return None
        ver = int(version)
        if ver < 0:
            return self._pending[(replica, kid)], None
        return self._vals[int(gseq)], ver

    def _decode(self, op: Op, res, i):
        kind = op.kind
        if kind == "read":
            if res["mm_used"][i]:
                self.grant_log.append((op.key, int(res["wts"][i]),
                                       int(res["rts"][i]),
                                       int(res["version"][i])))
            return self._read_result(self._keys[op.key], op.replica,
                                     res["found"][i], res["version"][i],
                                     res["gseq"][i])
        if kind == "write":
            kid = self._keys[op.key]
            self._pending[(op.replica, kid)] = op.value
            self._pending_n[(op.replica, kid)] = self._pending_n.get(
                (op.replica, kid), 0) + 1
            node = op.replica // self._rpn
            self._qmirror[node].append(
                (kid, op.value, op.replica,
                 -1 if op.wr_lease is None else op.wr_lease))
            self._drains(res, i, node=node)
            return None
        if kind == "fence":
            self._drains(res, i)
            return int(res["gmax"][i])
        if kind in ("mm_write", "publish"):
            gs = int(res["gseq"][i])
            self._vals[gs] = op.value
            self._writes_since_prune += 1
            g = (op.key, int(res["wts"][i]), int(res["rts"][i]),
                 int(res["version"][i]))
            self.grant_log.append(g)
            if kind == "mm_write":
                return g[1], g[2], g[3]
            return g[1], g[2]
        if kind == "mm_read":
            if not res["found"][i]:
                return None
            g = (op.key, int(res["wts"][i]), int(res["rts"][i]),
                 int(res["version"][i]))
            self.grant_log.append(g)
            return (self._vals[int(res["gseq"][i])], g[3], g[1], g[2])
        raise ValueError(f"unknown op kind {kind!r}")

    # ------------------------------------------------------------ batched
    def peek(self, key, replica: int = 0) -> bool:
        kid = self._keys.get(key)
        if kid is None:
            return False
        s1 = self._meta[kid][0]
        tags = np.asarray(self._af.rp.tag[replica, s1])[:-1]
        w = np.nonzero(tags == kid)[0]
        if w.size == 0:
            return False
        rts = int(np.asarray(self._af.rp.rts[replica, s1])[w[0]])
        return bool(protocol.valid(int(np.asarray(self._af.rp.cts[replica])),
                                   rts))

    def read_batch(self, keys: Sequence, replica: int = 0):
        """The two-phase batched read (backend contract), vectorized:
        phase 1 serves every replica-tier lease hit with ONE
        ``state.tier_probe`` call over the whole batch; phase 2 serves
        the miss subset with the vectorized miss pass (the batched grant
        pipeline, DESIGN.md §9) — conflict-free rounds, one batched TSU
        grant per round — falling back to the exact op-scan under
        ``pipeline="scan"`` or when the subset is so conflict-ridden the
        round budget (``max(_MIN_ROUND_BUDGET, misses // 4)``) is blown."""
        return self.read_batch_async(keys, replica).result()

    def read_batch_async(self, keys: Sequence, replica: int = 0):
        """The overlapped batched read (backend contract): everything
        device-side — the phase-1 probe, the miss pass, and on the
        sharded engine the NEXT batch's grant exchange — is dispatched
        before this returns; only the miss subset's host-side payload
        decode waits in the handle.  A serving loop
        (``Server.serve_stream``) dispatches batch N+1 while batch N's
        decode is still pending, hiding the exchange + decode latency
        under device compute."""
        if not keys:
            return ReadBatchHandle(lambda: [])
        B = len(keys)
        with obs.span("fabric.pack", n_ops=B):
            keymap = self._keys
            try:
                kids = [keymap[k] for k in keys]  # hot path: interned keys
            except KeyError:
                kids = [self._kid(k) for k in keys]
            kids_np = np.asarray(kids, np.int32)
            if self._meta_dev is None:
                # whole table at its (power-of-two) capacity: stable shapes
                self._meta_dev = jnp.asarray(self._meta[:, 0])
        with obs.span("fabric.fast_probe", n_ops=B):
            packed, lru2, tick2, g2, r2 = self._fast_read(
                self._af.rp, self._af.rp_gseq, self._af.rp_tick, self._af.g,
                self._af.r, self._meta_dev, jnp.asarray(kids_np),
                np.int32(replica))
            obs.fence(packed, "fabric.fast_probe.device")
        with obs.span("fabric.donate"):
            self._af = self._af._replace(rp=self._af.rp._replace(lru=lru2),
                                         rp_tick=tick2, g=g2, r=r2)
        with obs.span("fabric.decode", n_ops=B):
            packed = np.asarray(packed)
            hit = packed[0].astype(bool)
            ver, gseq = packed[1], packed[2]
            vals, pend = self._vals, self._pending
            if hit.all():
                self._fast_read_batches += 1
                ready = [(vals[g], v) if v >= 0
                         else (pend[(replica, k)], None)
                         for k, v, g in zip(kids, ver.tolist(),
                                            gseq.tolist())]
                return ReadBatchHandle(lambda: ready)
            out: List = [None] * B
            for i in np.nonzero(hit)[0]:
                v = int(ver[i])
                out[i] = ((pend[(replica, kids[i])], None) if v < 0
                          else (vals[int(gseq[i])], v))
            miss = np.nonzero(~hit)[0]
        with obs.span("fabric.miss_pass", misses=int(miss.size)):
            decode = (self._read_misses_dispatch(keys, kids_np, miss,
                                                 replica)
                      if self.pipeline == "batched" else None)
        if decode is None:          # scan pipeline / round-budget bail
            res = self.apply([Op("read", keys[i], replica=replica)
                              for i in miss])
            served = [r for _, r in res]
            for j, i in enumerate(miss):
                out[i] = served[j]
            return ReadBatchHandle(lambda: out)

        def finish():
            with obs.span("fabric.miss_pass", misses=int(miss.size)):
                served = decode()
            for j, i in enumerate(miss):
                out[i] = served[j]
            return out

        return ReadBatchHandle(finish)

    def _read_misses_dispatch(self, keys, kids_np, miss, replica):
        """Dispatch the miss subset through the vectorized miss pass:
        graph-colored conflict-free rounds (`pipeline.conflict_rounds`),
        ONE jitted pass over the padded subset.  Returns a decode
        closure that resolves results — grant-log appends and payload
        lookups — in op order (the deferred half of
        ``read_batch_async``), or None to signal the op-scan fallback
        when the subset is too conflict-ridden to pay off."""
        m = miss.size
        with obs.span("fabric.pack", misses=int(m)):
            kids_m = kids_np[miss]
            meta = self._meta[kids_m]
            rounds = P_.conflict_rounds(kids_m, meta[:, 0], meta[:, 1])
            if len(rounds) > max(_MIN_ROUND_BUDGET, m // 4):
                return None
            # coarse pow2 buckets (M >= 32 lanes, R >= 4 rounds): the padded
            # lanes/rounds are fully masked no-ops, and near-miss shape churn
            # (15 vs 17 misses, 1 vs 2 rounds) must not trigger recompiles on
            # the serving hot path
            M = max(32, _next_pow2(m))
            R = max(4, _next_pow2(len(rounds)))
            masks = P_.round_masks(rounds, R, M)
            ops = np.zeros((4, M), np.int32)
            ops[0, :m] = kids_m
            ops[1, :m] = meta[:, 0]
            ops[2, :m] = meta[:, 1]
            ops[3, :m] = meta[:, 2]
            node = replica // self._rpn
        with obs.span("fabric.exchange", lanes=M, rounds=R):
            args = (jnp.asarray(ops), jnp.asarray(masks))
            af = self._xin()
        with obs.span("fabric.scan", misses=int(m)):
            af, res = self._miss_run(
                af, *args, np.int32(replica), np.int32(node),
                jnp.int32(self.cfg.rd_lease), jnp.int32(self.cfg.wr_lease))
            self._xout(af)
            obs.fence(res, "fabric.scan.device")
        def decode():
            with obs.span("fabric.decode", misses=int(m)):
                r = np.asarray(jax.device_get(res))  # packed [7, M] block
                fields = dict(zip(P_.RES_FIELDS, r))
                out: List = []
                for j, i in enumerate(miss):
                    if fields["mm_used"][j]:
                        self.grant_log.append(
                            (keys[i], int(fields["wts"][j]),
                             int(fields["rts"][j]),
                             int(fields["version"][j])))
                    out.append(self._read_result(int(kids_m[j]), replica,
                                                 fields["found"][j],
                                                 fields["version"][j],
                                                 fields["gseq"][j]))
            return out

        return decode

    def _note_write_batch(self) -> None:
        self._write_batches += 1

    def write_batch(self, items, replica: int = 0, wr_lease=None) -> None:
        """Batched posted writes (backend contract), vectorized: the whole
        storm runs through the batched write pass (DESIGN.md §11) —
        graph-colored conflict-free rounds with the lane-static drain
        schedule (``pipeline.write_schedule``), ONE batched TSU
        write-through grant per round, and on the sharded fabric ONE
        packed collective per batch — falling back
        to the exact op-scan under ``pipeline="scan"`` or when the batch
        is so conflict-ridden the round budget
        (``max(_MIN_ROUND_BUDGET, writes // 2)``) is blown."""
        items = list(items)
        if not items:
            return
        self._note_write_batch()
        served = False
        if self._write_run is not None:
            with obs.span("fabric.write_pass", n_ops=len(items)):
                served = self._write_batch_batched(items, replica, wr_lease)
        if not served:
            self.apply([Op("write", k, v, replica=replica,
                           wr_lease=wr_lease) for k, v in items])

    def _write_batch_batched(self, items, replica, wr_lease) -> bool:
        """Serve a posted-write batch with the vectorized write pass:
        resolve the lane-static drain schedule and graph-colored rounds
        on the host (``pipeline.write_schedule``), run all rounds as ONE
        jitted pass over the padded batch, then replay the returned drain
        log — payload handoffs and grant-log appends — in op order via
        the op-scan's own ``_drains`` decoder.  Returns False to signal
        the op-scan fallback when the batch is too conflict-ridden."""
        B = len(items)
        node = replica // self._rpn
        with obs.span("fabric.pack", n_ops=B):
            kids = np.asarray([self._kid(k) for k, _ in items], np.int32)
            meta = self._meta[kids]
            wl = -1 if wr_lease is None else wr_lease
            pending = [(k, *self._meta[k].tolist(), r, w)
                       for k, _, r, w in self._qmirror[node]]
            rounds, sched = P_.write_schedule(
                kids, meta[:, 0], meta[:, 1], meta[:, 2], replica, wl,
                pending, self.cfg.max_in_flight)
            if len(rounds) > max(_MIN_ROUND_BUDGET, B // 2):
                return False
            M = max(32, _next_pow2(B))
            R = max(4, _next_pow2(len(rounds)))
            masks = P_.round_masks(rounds, R, M)
            ops = np.zeros((4, M), np.int32)
            ops[0, :B] = kids
            ops[1, :B] = meta[:, 0]
            ops[2, :B] = meta[:, 1]
            ops[3, :B] = meta[:, 2]
            sched = np.pad(sched, ((0, 0), (0, M - B)))
        with obs.span("fabric.exchange", lanes=M, rounds=R):
            args = (jnp.asarray(ops), jnp.asarray(sched),
                    jnp.asarray(masks))
            af = self._xin()
        with obs.span("fabric.scan", n_ops=B):
            af, res = self._write_run(
                af, *args, np.int32(replica), np.int32(node),
                jnp.int32(wl), jnp.int32(self.cfg.rd_lease),
                jnp.int32(self.cfg.wr_lease))
            self._xout(af)
            obs.fence(res, "fabric.scan.device")
        with obs.span("fabric.decode", n_ops=B):
            res = np.asarray(jax.device_get(res))  # packed [6, M] block
            f = dict(zip(P_.WRITE_RES_FIELDS, res))
            # the drain decoder reads per-op drain-log ROWS; a write op
            # drains at most once, so each lane is a one-column row
            rd = {"dcount": f["dcount"]}
            rd.update({k: f[k][:, None] for k in P_.WRITE_RES_FIELDS[1:]})
            for i, (k, v) in enumerate(items):
                kid = int(kids[i])
                self._pending[(replica, kid)] = v
                self._pending_n[(replica, kid)] = self._pending_n.get(
                    (replica, kid), 0) + 1
                self._qmirror[node].append((kid, v, replica, wl))
                self._drains(rd, i, node=node)
        if self._writes_since_prune >= _PRUNE_EVERY:
            with obs.span("fabric.donate"):
                self.prune_payloads()
        return True

    # ------------------------------------------------------------ scalar
    def read(self, key, replica: int = 0):
        return self.apply([Op("read", key, replica=replica)])[0][1]

    def write(self, key, value, replica: int = 0, wr_lease=None) -> None:
        self.apply([Op("write", key, value, replica=replica,
                       wr_lease=wr_lease)])

    def fence(self) -> int:
        """Drain every node's posted-write queue, then jump all client
        clocks to the global max (§11b).  On the sharded batched engine
        the fence runs as the dedicated vectorized fence pass (one jitted
        call, one gather collective) instead of paying the op-scan's
        per-drain dispatch; the single-device fabric keeps the op-scan
        fence as the bit-identical reference path (both are
        parity-checked against ``HostFabric``)."""
        if self._gather_run is not None and self._fence_run is not None:
            out = self._fence_batched()
            if out is not None:
                return out
        return self.apply([Op("fence")])[0][1]

    def _fence_batched(self) -> Optional[int]:
        """Serve a fence with the vectorized fence pass: every queued
        entry (all nodes, node-major FIFO — the host drain order) becomes
        one schedule lane, rounds are conflict-free segments
        (``pipeline.fence_schedule``), and the drain log replays through
        the op-scan's own ``_drains`` decoder.  Returns None to signal
        the op-scan fallback when the drain set is too conflict-ridden."""
        entries = []
        for nd in range(self.n_nodes):
            for kid, _v, rep, wl in self._qmirror[nd]:
                s1, s2, shard = self._meta[kid]
                entries.append((kid, s1, s2, shard, rep, wl, nd))
        D0 = len(entries)
        with obs.span("fabric.pack", n_ops=D0):
            rounds, sched = P_.fence_schedule(entries)
            if len(rounds) > max(_MIN_ROUND_BUDGET, max(1, D0) // 2):
                return None
            D = max(8, _next_pow2(max(1, D0)))
            R = max(4, _next_pow2(len(rounds)))
            sched = np.pad(sched, ((0, 0), (0, D - D0)))
            masks = P_.round_masks(rounds, R, D)
        with obs.span("fabric.exchange", lanes=D, rounds=R):
            args = (jnp.asarray(sched), jnp.asarray(masks))
            af = self._xin()
        with obs.span("fabric.scan", n_ops=D0):
            af, res, gmax = self._fence_run(
                af, *args, jnp.int32(self.cfg.rd_lease),
                jnp.int32(self.cfg.wr_lease))
            self._xout(af)
            obs.fence(res, "fabric.scan.device")
        with obs.span("fabric.decode", n_ops=D0):
            res = np.asarray(jax.device_get(res))   # packed [6, D] block
            f = dict(zip(P_.WRITE_RES_FIELDS, res))
            # ONE fence op draining D0 entries: the decoder reads per-op
            # drain-log rows, so the whole lane axis is row 0
            rd = {"dcount": np.asarray([D0], np.int32)}
            rd.update({k: f[k][None, :]
                       for k in P_.WRITE_RES_FIELDS[1:]})
            self._drains(rd, 0)
        if self._writes_since_prune >= _PRUNE_EVERY:
            with obs.span("fabric.donate"):
                self.prune_payloads()
        return int(jax.device_get(gmax))

    def mm_write(self, key, value, wr_lease=None):
        return self.apply([Op("mm_write", key, value,
                              wr_lease=wr_lease)])[0][1]

    def publish(self, key, value, node: int = 0, wr_lease=None):
        return self.apply([Op("publish", key, value, node=node,
                              wr_lease=wr_lease)])[0][1]

    def mm_read(self, key):
        return self.apply([Op("mm_read", key)])[0][1]

    # ------------------------------------------------------------ views
    def memts(self, key) -> int:
        kid = self._keys.get(key)
        if kid is None:
            return 0
        shard = self._meta[kid][2]
        tags = np.asarray(self._af.tsu.tag[shard, 0])
        hit = np.nonzero(tags == kid)[0]
        if hit.size == 0:
            return 0
        return int(np.asarray(self._af.tsu.memts[shard, 0])[hit[0]])

    @property
    def fast_read_batches(self) -> int:
        """All-hit batches served by phase 1 alone — a FabricStats field
        (reported by ``stats()`` so backend equality assertions cover it);
        this accessor is kept for telemetry callers."""
        return self._fast_read_batches

    def stats(self) -> Dict[str, int]:
        g = np.asarray(jax.device_get(self._af.g))
        out = {k: int(g[i]) for i, k in enumerate(_G_KEYS)}
        out["wb_evictions"] = 0
        out["inval_msgs"] = 0
        out["fast_read_batches"] = self._fast_read_batches
        out["write_batches"] = self._write_batches
        return out

    def replica_stats(self, replica: int = 0) -> Dict[str, int]:
        r = np.asarray(jax.device_get(self._af.r))[replica]
        out = {k: 0 for k in self.stats()}
        out.update({k: int(r[i]) for i, k in enumerate(_R_KEYS)})
        return out


class ShardedArrayFabric(ArrayFabric):
    """The mesh-placed fabric: TSU shards on devices along a ``fabric`` axis.

    HALCONE's TSU is physically distributed — one timestamp storage unit
    per HBM stack, coherence actions executed local to the memory they
    guard.  This backend realizes that placement: the ``[n_shards,
    capacity]`` TSU table (plus the per-shard grant sequencers and
    version/gseq side arrays) is partitioned over the ``fabric`` mesh axis
    with ``NamedSharding`` and the op-scan runs as a ``repro.sharding.
    shard_map`` body.  Under the default batched grant pipeline the owned
    TSU rows are exchanged as ONE packed collective per batch (DESIGN.md
    §9); under ``pipeline="scan"`` each op's TSU transition executes only
    on its key's owning device and the grant hops back per scan step (the
    PR-4 schedule).  Either way the protocol-level cross-shard traffic is
    what the ``bytes_inter_gpu`` counter measures (Fig. 10) — it counts
    home-shard misses, not mesh messages, so it is identical across
    pipelines and mesh sizes.  Client tiers and the write-queue rings
    stay replicated across the axis.

    Still a ``FabricBackend``, still bit-identical to ``HostFabric`` and
    to the single-device ``ArrayFabric`` on any op trace
    (tests/test_fabric_parity.py runs the suite on a forced 8-device host
    mesh).  ``n_shards`` must be divisible by the mesh size; by default
    the largest dividing device count is used (``launch.mesh.
    make_fabric_mesh``), so a 1-device host degenerates to the
    single-device layout under the same shard_map entry point.
    """

    def __init__(self, cfg: FabricConfig = FabricConfig(),
                 n_nodes: int = 1, replicas_per_node: int = 1,
                 mesh=None, devices=None, pipeline: str = "batched"):
        cfg = _bounded(cfg)
        if mesh is None:
            from repro.launch.mesh import make_fabric_mesh
            mesh = make_fabric_mesh(n_shards=cfg.n_shards, devices=devices)
        super().__init__(cfg, n_nodes, replicas_per_node, mesh=mesh,
                         pipeline=pipeline)

    @property
    def n_shard_devices(self) -> int:
        return int(self.mesh.devices.size)


def default_fabric(cfg: FabricConfig = FabricConfig(),
                   n_nodes: int = 1,
                   replicas_per_node: int = 1,
                   pipeline: str = "batched") -> ArrayFabric:
    """The production entry point servers/adapters default to: mesh-placed
    TSU shards (``ShardedArrayFabric``) whenever the config's shards can
    actually spread over more than one device, the plain single-device
    ``ArrayFabric`` otherwise (including n_shards=1 configs on
    multi-device hosts — a 1-device mesh would pay the shard_map masked
    execution for zero placement benefit).

    Both run the batched grant pipeline by default: ONE packed grant
    collective per batch and the vectorized miss pass (DESIGN.md §9), so
    sharded placement no longer trades batch throughput for locality.
    ``pipeline="scan"`` selects the per-op schedule for ordering-sensitive
    debugging."""
    cfg = _bounded(cfg)
    if len(jax.devices()) > 1:
        from repro.launch.mesh import make_fabric_mesh
        mesh = make_fabric_mesh(n_shards=cfg.n_shards)
        if int(mesh.devices.size) > 1:
            return ShardedArrayFabric(cfg, n_nodes, replicas_per_node,
                                      mesh=mesh, pipeline=pipeline)
    return ArrayFabric(cfg, n_nodes, replicas_per_node, pipeline=pipeline)
