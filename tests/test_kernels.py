"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle, swept
over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lease_probe import lease_probe
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd_chunk import ssd_chunk
from repro.models.ssm import ssd_chunked


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,Sq,Sk,Hq,Hkv,D", [
    (1, 128, 128, 4, 4, 64),
    (2, 256, 256, 8, 2, 64),      # GQA 4:1
    (1, 128, 384, 4, 1, 128),     # MQA, rectangular
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 64)])
def test_flash_attention(B, Sq, Sk, Hq, Hkv, D, dtype, causal, window):
    if causal and Sq != Sk:
        pytest.skip("causal assumes aligned q/k")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          bq=64, bk=64, interpret=True)
    want = ref.attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,Sk,Hq,Hkv,D,kv_len", [
    (2, 512, 4, 4, 64, 384),
    (1, 1024, 8, 2, 128, 1000),
    (4, 256, 4, 1, 64, 1),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, Sk, Hq, Hkv, D, kv_len, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (B, 1, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Sk, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Sk, Hkv, D), dtype)
    out = decode_attention(q, k, v, kv_len, bk=128, interpret=True)
    want = ref.attention_ref(q, k, v, causal=False, kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("R,D", [(64, 256), (128, 960), (32, 80)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm(R, D, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    x = jax.random.normal(ks[0], (R, D), dtype)
    w = jax.random.normal(ks[1], (D,), jnp.float32) * 0.1
    out = rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


@pytest.mark.parametrize("B,nc,Q,H,P,N", [
    (1, 2, 32, 2, 16, 16),
    (2, 4, 64, 4, 32, 32),
])
def test_ssd_chunk_kernel(B, nc, Q, H, P, N):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, nc, Q, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, nc, Q, H)))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.5))
    Bc = jax.random.normal(ks[3], (B, nc, Q, H, N), jnp.float32)
    Cc = jax.random.normal(ks[4], (B, nc, Q, H, N), jnp.float32)
    y, st, cum = ssd_chunk(x, dt, A, Bc, Cc, interpret=True)
    for b in range(B):
        for c in range(nc):
            for h in range(H):
                yr, sr, cr = ref.ssd_chunk_ref(x[b, c, :, h], dt[b, c, :, h],
                                               A[h], Bc[b, c, :, h],
                                               Cc[b, c, :, h])
                np.testing.assert_allclose(y[b, c, :, h], yr, rtol=1e-4,
                                           atol=1e-4)
                np.testing.assert_allclose(st[b, c, h], sr, rtol=1e-4,
                                           atol=1e-4)
                np.testing.assert_allclose(cum[b, c, :, h], cr, rtol=1e-5,
                                           atol=1e-5)


def test_ssd_kernel_matches_full_ssm_path():
    """Kernel intra-chunk + jnp inter-chunk == models.ssm.ssd_chunked."""
    B, S, H, P, N, Q = 2, 128, 4, 16, 16, 32
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.5))
    Bc = jax.random.normal(ks[3], (B, S, 1, N), jnp.float32)
    Cc = jax.random.normal(ks[4], (B, S, 1, N), jnp.float32)
    y_ref, final_ref = ssd_chunked(x, dt, A, Bc, Cc, Q)

    nc = S // Q
    xc = x.reshape(B, nc, Q, H, P)
    dtc = dt.reshape(B, nc, Q, H)
    Bh = jnp.broadcast_to(Bc.reshape(B, nc, Q, 1, N), (B, nc, Q, H, N))
    Ch = jnp.broadcast_to(Cc.reshape(B, nc, Q, 1, N), (B, nc, Q, H, N))
    y_in, st, cum = ssd_chunk(xc, dtc, A, Bh, Ch, interpret=True)
    # inter-chunk combine (jnp)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                    # [B,nc,H]
    state = jnp.zeros((B, H, N, P))
    ys = []
    for c in range(nc):
        decay_in = jnp.exp(cum[:, c])                          # [B,Q,H]
        y_int = jnp.einsum("bqhn,bhnp->bqhp",
                           Ch[:, c] * decay_in.transpose(0, 1, 2)[..., None],
                           state)
        ys.append(y_in[:, c] + y_int)
        state = state * chunk_decay[:, c][:, :, None, None] + st[:, c]
    y = jnp.stack(ys, 1).reshape(B, S, H, P)
    np.testing.assert_allclose(y, y_ref, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(state, final_ref, rtol=1e-3, atol=1e-3)


def _lease_probe_inputs(N, W, seed=0):
    rng = np.random.default_rng(seed)
    tag_rows = rng.integers(-1, 50, (N, W)).astype(np.int32)
    rts_rows = rng.integers(0, 40, (N, W)).astype(np.int32)
    cts = rng.integers(0, 40, (N,)).astype(np.int32)
    addr = rng.integers(0, 50, (N,)).astype(np.int32)
    mwts = rng.integers(0, 40, (N,)).astype(np.int32)
    mrts = mwts + rng.integers(1, 10, (N,)).astype(np.int32)
    # make hit ways unique per row (engine invariant: one copy per cache)
    for i in range(N):
        seen = set()
        for j in range(W):
            if tag_rows[i, j] in seen:
                tag_rows[i, j] = -2 - j
            seen.add(tag_rows[i, j])
    return tag_rows, rts_rows, cts, addr, mwts, mrts


_PROBE_OUTS = ["tag_hit", "hit", "way", "row_rts", "nwts", "nrts", "ncts"]


@pytest.mark.parametrize("N,W", [(64, 4), (256, 16), (100, 8)])
def test_lease_probe(N, W):
    tag_rows, rts_rows, cts, addr, mwts, mrts = _lease_probe_inputs(N, W)
    got = lease_probe(jnp.asarray(tag_rows), jnp.asarray(rts_rows),
                      jnp.asarray(cts), jnp.asarray(addr),
                      jnp.asarray(mwts), jnp.asarray(mrts), interpret=True)
    want = ref.lease_probe_ref(tag_rows, rts_rows, cts, addr, mwts, mrts)
    for g, w, name in zip(got, want, _PROBE_OUTS):
        g, w = np.asarray(g), np.asarray(w)
        if name == "way":           # way only meaningful on tag hits
            eq = (tag_rows == addr[:, None]).any(-1)
            np.testing.assert_array_equal(g[eq], w[eq], err_msg=name)
        else:
            np.testing.assert_array_equal(g, w, err_msg=name)


def test_lease_probe_duplicate_tags_use_first_way():
    """The engine can hold a stale duplicate of a tag (coherence-miss
    installs go to a victim way while the expired copy stays live): the
    probe must read the FIRST matching way, exactly like argmax/ref —
    not mix the ways' timestamps."""
    tag_rows = np.array([[7, 7, -1, -1],
                         [7, -1, 7, -1],
                         [3, 7, 7, 7]], np.int32)
    rts_rows = np.array([[5, 20, 0, 0],
                         [20, 0, 5, 0],
                         [9, 2, 30, 40]], np.int32)
    cts = np.array([10, 10, 10], np.int32)
    addr = np.array([7, 7, 7], np.int32)
    mwts = np.zeros(3, np.int32)
    mrts = np.full(3, 12, np.int32)
    got = lease_probe(*map(jnp.asarray, (tag_rows, rts_rows, cts, addr,
                                         mwts, mrts)), interpret=True)
    want = ref.lease_probe_ref(tag_rows, rts_rows, cts, addr, mwts, mrts)
    for g, w, name in zip(got, want, _PROBE_OUTS):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)
    # row 0: first way rts=5 < cts -> lease-expired despite the rts=20 dup
    np.testing.assert_array_equal(np.asarray(got[1]), [False, True, False])


@pytest.mark.parametrize("interpret", [
    True,
    pytest.param(False, marks=pytest.mark.skipif(
        jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm"),
        reason="compiled Pallas needs a TPU/GPU backend")),
])
@pytest.mark.parametrize("seed", [0, 1, 2])
def test_lease_probe_matches_protocol(interpret, seed):
    """Bit-for-bit parity of the kernel's install math against
    core.protocol (Algorithms 1-5) on randomized tag/rts/cts batches —
    the engine's hot path is pinned to the protocol's decision surface."""
    from repro.core import protocol
    tag_rows, rts_rows, cts, addr, mwts, mrts = \
        _lease_probe_inputs(192, 8, seed)
    got = lease_probe(jnp.asarray(tag_rows), jnp.asarray(rts_rows),
                      jnp.asarray(cts), jnp.asarray(addr),
                      jnp.asarray(mwts), jnp.asarray(mrts),
                      interpret=interpret)
    tag_hit, hit, way, row_rts, nwts, nrts, ncts = map(np.asarray, got)
    lease = protocol.install(jnp.asarray(cts), jnp.asarray(mwts),
                             jnp.asarray(mrts))
    np.testing.assert_array_equal(nwts, np.asarray(lease.wts))
    np.testing.assert_array_equal(nrts, np.asarray(lease.rts))
    np.testing.assert_array_equal(
        ncts, np.asarray(protocol.cts_after_write(jnp.asarray(cts),
                                                  lease.wts)))
    # validity: hit == tag match AND protocol.valid(cts, rts of the way)
    eq = tag_rows == addr[:, None]
    want_tag_hit = eq.any(-1)
    rts_way = np.where(want_tag_hit,
                       np.take_along_axis(rts_rows, eq.argmax(-1)[:, None],
                                          1)[:, 0], 0)
    np.testing.assert_array_equal(tag_hit, want_tag_hit)
    np.testing.assert_array_equal(
        hit, want_tag_hit & np.asarray(protocol.valid(cts, rts_way)))
    np.testing.assert_array_equal(row_rts, rts_way)


# ------------------------------------------------ fused miss/write rounds
def _miss_round_inputs(N, W1, W2, C, seed=0):
    rng = np.random.default_rng(seed)
    r = lambda lo, hi, shp: rng.integers(lo, hi, shp).astype(np.int32)
    return (r(-1, 30, (N, W1)), r(0, 40, (N, W1)), r(-1, 30, (N, W2)),
            r(0, 40, (N, W2)), r(0, 40, (N, W2)), r(-1, 30, (N, C)),
            r(0, 70000, (N, C)), r(0, 40, N), r(0, 40, N), r(0, 30, N),
            r(0, 2, N), np.full(N, 10, np.int32))


_MISS_OUTS = ["th1", "h1", "way1", "th2", "h2", "way2", "fnd", "tway",
              "mwts", "mrts", "nmem", "ovf", "nwa", "nra", "nw1", "nr1"]
_WAYS = {"way1", "way2", "tway"}           # meaningful only on a tag hit


@pytest.mark.parametrize("interpret", [
    True,
    pytest.param(False, marks=pytest.mark.skipif(
        jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm"),
        reason="compiled Pallas needs a TPU/GPU backend")),
])
@pytest.mark.parametrize("N,W1,W2,C,seed", [
    (64, 4, 8, 16, 0), (256, 2, 4, 64, 1), (96, 8, 2, 8, 2)])
def test_miss_round_kernel(interpret, N, W1, W2, C, seed):
    """The fused miss-pass round kernel (3 probes + Algorithm 3 read
    grant + both Algorithm 1/2 install levels) is bit-identical to the
    protocol-derived oracle, interpret and compiled."""
    from repro.kernels.tier_pass import miss_round
    ins = _miss_round_inputs(N, W1, W2, C, seed)
    got = miss_round(*map(jnp.asarray, ins), interpret=interpret)
    want = ref.miss_round_ref(*map(jnp.asarray, ins))
    tags = {"way1": ins[0], "way2": ins[2], "tway": ins[5]}
    for g, w, name in zip(got, want, _MISS_OUTS):
        g, w = np.asarray(g), np.asarray(w)
        if name in _WAYS:
            eq = (tags[name] == ins[9][:, None]).any(-1)
            np.testing.assert_array_equal(g[eq], w[eq], err_msg=name)
        else:
            np.testing.assert_array_equal(g, w, err_msg=name)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_miss_round_matches_state_rules(seed):
    """Pin the fused kernel's grant + install math to core.state /
    core.protocol: the TSU read grant equals ``state.tsu_lease`` and the
    two install levels equal chained ``state.install_lease`` calls, on
    lanes where the kernel's masks make them observable."""
    from repro.core import state as S
    from repro.kernels.tier_pass import miss_round
    N = 128
    ins = _miss_round_inputs(N, 4, 4, 32, seed)
    (th1, h1, way1, th2, h2, way2, fnd, tway, mwts, mrts, nmem, ovf,
     nwa, nra, nw1, nr1) = miss_round(*map(jnp.asarray, ins),
                                      interpret=True)
    cts1, cts2, addr, act, rd = (jnp.asarray(x) for x in ins[7:])
    # TSU grant: entry clock is the first-match row value (0 if absent)
    eqt = jnp.asarray(ins[5]) == addr[:, None]
    first = eqt & (jnp.cumsum(eqt.astype(jnp.int32), -1) == 1)
    memts = jnp.where(eqt.any(-1),
                      jnp.sum(jnp.where(first, jnp.asarray(ins[6]), 0), -1),
                      0)
    gr = S.tsu_lease(memts, jnp.zeros(memts.shape, bool), rd, rd)
    np.testing.assert_array_equal(np.asarray(mwts), np.asarray(gr.wts))
    np.testing.assert_array_equal(np.asarray(mrts), np.asarray(gr.rts))
    np.testing.assert_array_equal(np.asarray(nmem), np.asarray(gr.new_memts))
    # install chain: shared level then replica level
    wA, rA, _ = S.install_lease(cts2, mwts, mrts)
    np.testing.assert_array_equal(np.asarray(nwa), np.asarray(wA))
    np.testing.assert_array_equal(np.asarray(nra), np.asarray(rA))
    rwts = jnp.where(h2, ref._first_match_ref(
        jnp.asarray(ins[2]) == addr[:, None], jnp.asarray(ins[4])), nwa)
    rrts = jnp.where(h2, ref._first_match_ref(
        jnp.asarray(ins[2]) == addr[:, None], jnp.asarray(ins[3])), nra)
    w1, r1, _ = S.install_lease(cts1, rwts, rrts)
    np.testing.assert_array_equal(np.asarray(nw1), np.asarray(w1))
    np.testing.assert_array_equal(np.asarray(nr1), np.asarray(r1))
    # mask algebra: the kernel's flags obey the round body's lattice
    th1, h1, th2, h2, fnd = map(np.asarray, (th1, h1, th2, h2, fnd))
    assert not (h1 & ~th1).any() and not (h2 & ~th2).any()
    assert not (th1 & ~np.asarray(act).astype(bool)).any()
    assert not (th2 & np.asarray(h1)).any()
    assert not (fnd & np.asarray(h2)).any()


@pytest.mark.parametrize("interpret", [
    True,
    pytest.param(False, marks=pytest.mark.skipif(
        jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm"),
        reason="compiled Pallas needs a TPU/GPU backend")),
])
@pytest.mark.parametrize("N,C,seed", [(64, 16, 0), (256, 64, 1), (40, 8, 2)])
def test_write_grant_kernel(interpret, N, C, seed):
    """The fused write-side TSU kernel (probe + lexicographic victim +
    mm_write grant) is bit-identical to the oracle and to
    ``state.victim_lex``/``state.tsu_lease``, interpret and compiled."""
    from repro.core import state as S
    from repro.kernels.tier_pass import write_grant
    rng = np.random.default_rng(seed)
    ts_tag = rng.integers(-1, 20, (N, C)).astype(np.int32)
    ts_mem = rng.integers(0, 70000, (N, C)).astype(np.int32)
    ts_seq = rng.integers(0, 50, (N, C)).astype(np.int32)
    addr = rng.integers(0, 20, N).astype(np.int32)
    wl = rng.integers(1, 10, N).astype(np.int32)
    got = write_grant(*map(jnp.asarray, (ts_tag, ts_mem, ts_seq, addr, wl)),
                      interpret=interpret)
    want = ref.write_grant_ref(*map(jnp.asarray,
                                    (ts_tag, ts_mem, ts_seq, addr, wl)))
    for g, w, name in zip(got, want,
                          ["th", "way", "full", "wts", "rts", "nmem",
                           "ovf"]):
        np.testing.assert_array_equal(np.asarray(g), np.asarray(w),
                                      err_msg=name)
    # pin the victim rule to state.victim_lex on the miss lanes
    th, way = got[0], got[1]
    pad = lambda a: jnp.concatenate(
        [jnp.asarray(a)[:, None, :], jnp.zeros((N, 1, 1), jnp.int32)], -1)
    vic = S.victim_lex(pad(ts_tag), pad(ts_mem), pad(ts_seq),
                       jnp.arange(N), jnp.zeros(N, jnp.int32))
    np.testing.assert_array_equal(np.asarray(way)[~np.asarray(th)],
                                  np.asarray(vic)[~np.asarray(th)])
