"""Model configuration for the 10-arch pool (+ reduced smoke variants)."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Policy:
    """Numerics / memory policy. Low-precision optimizer state is the standard
    >=200B-param trick to fit 16 GB/chip HBM (documented in DESIGN.md)."""

    param_dtype: jnp.dtype = jnp.float32
    compute_dtype: jnp.dtype = jnp.bfloat16
    moment_dtype: jnp.dtype = jnp.float32
    cache_dtype: jnp.dtype = jnp.bfloat16
    remat: bool = True


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    rope_theta: float = 1e4
    rms_eps: float = 1e-6
    tie_embeddings: bool = False
    causal: bool = True             # False => encoder-only (no decode step)
    # sliding-window pattern (gemma3): every `global_every`-th layer is global,
    # the rest use `window`-token local attention.  0 => all layers global.
    window: int = 0
    global_every: int = 0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1              # every k-th layer is MoE (llama4: 2)
    first_dense: int = 0            # first N layers dense (deepseek: 1)
    capacity_factor: float = 1.25
    moe_shard_map: bool = True      # explicit all_to_all dispatch (§Perf)
    # MLA (deepseek)
    mla_absorb: bool = True         # weight-absorption decode (§Perf)
    q_lora: int = 0
    kv_lora: int = 0
    rope_head_dim: int = 0
    nope_head_dim: int = 0
    v_head_dim: int = 0
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    d_conv: int = 4
    expand: int = 2
    attn_every: int = 0             # hybrid: every k-th layer is the shared attn block
    # modality frontend stub
    frontend: str = "none"          # none | audio | vision
    d_frontend: int = 0
    n_patch_tokens: int = 0
    # misc
    attn_chunk: int = 1024          # q-block size for memory-efficient attention
    ssd_chunk: int = 256
    policy: Policy = dataclasses.field(default_factory=Policy)

    # ---- derived ----
    @property
    def d_inner(self) -> int:       # SSM inner width
        return self.expand * self.d_model

    @property
    def q_per_kv(self) -> int:
        return max(1, self.n_heads // max(1, self.n_kv_heads))

    @property
    def is_mla(self) -> bool:
        return self.kv_lora > 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k per assignment: SSM / hybrid / windowed."""
        return self.family in ("ssm", "hybrid") or self.window > 0

    def layer_kind(self, i: int) -> str:
        """Return block kind for layer index i."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid":
            if self.attn_every and (i + 1) % self.attn_every == 0:
                return "attn_shared"
            return "ssm"
        if self.family == "moe" or self.n_experts:
            if i < self.first_dense:
                return "dense"
            if (i - self.first_dense) % self.moe_every == self.moe_every - 1 or self.moe_every == 1:
                return "moe"
            return "dense"
        return "dense"

    def attn_window(self, i: int) -> int:
        """0 => full/global attention at layer i, else local window size."""
        if self.window == 0:
            return 0
        if self.global_every and (i + 1) % self.global_every == 0:
            return 0
        return self.window


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One (arch x input-shape) dry-run cell."""
    name: str                       # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                       # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = (
    ShapeCell("train_4k", "train", 4096, 256),
    ShapeCell("prefill_32k", "prefill", 32768, 32),
    ShapeCell("decode_32k", "decode", 32768, 128),
    ShapeCell("long_500k", "decode", 524288, 1),
)


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeCell, ...]:
    """Shape applicability per assignment (skips documented in DESIGN.md)."""
    out = []
    for s in SHAPES:
        if s.kind == "decode" and not cfg.causal:
            continue                          # encoder-only: no decode step
        if s.name == "long_500k" and not cfg.sub_quadratic:
            continue                          # pure full-attention archs skip
        out.append(s)
    return tuple(out)
