"""HALCONE lease-probe kernel: the protocol engine's hot inner loop
(tag compare + lease check + Algorithm 1/2 install math), batched over all
concurrent requests.  This is the paper's per-request coherence action as a
single fused VMEM pass — the Pallas face of repro.core.protocol."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _probe_kernel(tag_ref, rts_ref, cts_ref, addr_ref, mwts_ref, mrts_ref,
                  hit_ref, way_ref, nwts_ref, nrts_ref, ncts_ref):
    tags = tag_ref[...]                                 # [bn, W]
    rts = rts_ref[...]
    cts = cts_ref[...]
    addr = addr_ref[...]
    eq = tags == addr[:, None]
    tag_hit = eq.any(axis=-1)
    way = jnp.argmax(eq, axis=-1).astype(jnp.int32)
    row_rts = jnp.sum(jnp.where(eq, rts, 0), axis=-1)   # unique hit way
    hit = tag_hit & (cts <= row_rts)
    # protocol.install: Bwts = max(cts, Mwts); Brts = max(Bwts+1, Mrts)
    bwts = jnp.maximum(cts, mwts_ref[...])
    brts = jnp.maximum(bwts + 1, mrts_ref[...])
    hit_ref[...] = hit.astype(jnp.int32)
    way_ref[...] = way
    nwts_ref[...] = bwts
    nrts_ref[...] = brts
    ncts_ref[...] = jnp.maximum(cts, bwts)              # cts_after_write


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def lease_probe(tag_rows, rts_rows, cts, addr, mwts, mrts, *, bn=256,
                interpret=True):
    """tag_rows/rts_rows: [N, W]; cts/addr/mwts/mrts: [N] (int32).

    Returns (hit, way, new_wts, new_rts, new_cts), each [N] int32."""
    N, W = tag_rows.shape
    bn = min(bn, N)
    while N % bn:
        bn -= 1
    grid = (N // bn,)
    row = lambda i: (i, 0)
    vec = lambda i: (i,)
    outs = pl.pallas_call(
        _probe_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bn, W), row), pl.BlockSpec((bn, W), row),
                  pl.BlockSpec((bn,), vec), pl.BlockSpec((bn,), vec),
                  pl.BlockSpec((bn,), vec), pl.BlockSpec((bn,), vec)],
        out_specs=[pl.BlockSpec((bn,), vec)] * 5,
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.int32)] * 5,
        interpret=interpret,
    )(tag_rows, rts_rows, cts, addr, mwts, mrts)
    hit, way, nwts, nrts, ncts = outs
    return hit.astype(bool), way, nwts, nrts, ncts
