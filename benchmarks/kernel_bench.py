"""Pallas kernel microbench: interpret-mode on CPU validates + times the
reference XLA path (us/call).  Real-TPU timings come from the same wrappers
with use_pallas('tpu'); derived column reports the modelled VMEM-resident
HBM-traffic advantage vs the unfused jnp path."""
import time

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.kernels import ref


def _time(f, *args, iters=5):
    f(*args).block_until_ready() if hasattr(f(*args), "block_until_ready") \
        else None
    t0 = time.time()
    for _ in range(iters):
        out = f(*args)
        jax.block_until_ready(out)
    return (time.time() - t0) / iters * 1e6


def main(force=False):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    # flash attention: ref path timing + kernel HBM-traffic model
    B, S, H, D = 2, 1024, 8, 64
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.bfloat16)
    fa_ref = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=True))
    us = _time(fa_ref, q, k, v)
    qkv = 4 * B * S * H * D * 2
    scores = B * H * S * S * 4 * 2              # materialized fwd (w+r)
    emit("kernel/flash_attention", us,
         f"hbm_bytes_kernel={qkv};hbm_bytes_xla={qkv + scores};"
         f"saving={(qkv + scores)/qkv:.1f}x")
    # decode attention
    kc = jax.random.normal(ks[1], (B, 8192, H, D), jnp.bfloat16)
    vc = jax.random.normal(ks[2], (B, 8192, H, D), jnp.bfloat16)
    q1 = q[:, :1]
    da_ref = jax.jit(lambda q, k, v: ref.attention_ref(q, k, v, causal=False,
                                                       kv_len=8000))
    emit("kernel/decode_attention", _time(da_ref, q1, kc, vc),
         "streams_kv_once=True")
    # rmsnorm
    x = jax.random.normal(ks[0], (4096, 1024), jnp.bfloat16)
    w = jax.random.normal(ks[1], (1024,), jnp.float32) * 0.1
    rn = jax.jit(lambda x, w: ref.rmsnorm_ref(x, w))
    emit("kernel/rmsnorm", _time(rn, x, w), "fused_reads=1_vs_3")
    # ssd chunk
    import numpy as np
    Bc, nc, Q, Hh, P, N = 1, 4, 64, 4, 32, 32
    xs = jax.random.normal(ks[0], (Bc, nc, Q, Hh, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (Bc, nc, Q, Hh)))
    A = -jnp.exp(jax.random.uniform(ks[2], (Hh,)))
    Bm = jax.random.normal(ks[1], (Bc, nc, Q, Hh, N))
    Cm = jax.random.normal(ks[2], (Bc, nc, Q, Hh, N))
    from repro.kernels.ssd_chunk import ssd_chunk
    f = lambda: ssd_chunk(xs, dt, A, Bm, Cm, interpret=True)
    t0 = time.time(); jax.block_until_ready(f()); us0 = (time.time()-t0)*1e6
    emit("kernel/ssd_chunk_interpret", us0, "intra_chunk_vmem_resident=True")
    # lease probe
    from repro.kernels.lease_probe import lease_probe
    tags = jnp.asarray(np.random.randint(-1, 50, (1024, 16)), jnp.int32)
    rts = jnp.asarray(np.random.randint(0, 40, (1024, 16)), jnp.int32)
    vec = lambda: jnp.asarray(np.random.randint(0, 40, 1024), jnp.int32)
    t0 = time.time()
    jax.block_until_ready(lease_probe(tags, rts, vec(), vec(), vec(), vec(),
                                      interpret=True))
    emit("kernel/lease_probe_interpret", (time.time()-t0)*1e6,
         "protocol_hot_loop=fused")
    # fused miss/write-pass round kernels (ISSUE 8): steady-state us/call
    # vs the unfused path (2 lease_probe launches + jnp grant/install ops)
    from repro.kernels.tier_pass import miss_round, write_grant
    M, W1, W2, C = 512, 4, 16, 64
    r = lambda lo, hi, *shp: jnp.asarray(
        np.random.randint(lo, hi, shp), jnp.int32)
    miss_in = (r(-1, 50, M, W1), r(0, 40, M, W1), r(-1, 50, M, W2),
               r(0, 40, M, W2), r(0, 40, M, W2), r(-1, 50, M, C),
               r(0, 60000, M, C), r(0, 40, M), r(0, 40, M), r(0, 50, M),
               r(0, 2, M), jnp.full((M,), 10, jnp.int32))
    emit("kernel/miss_round_interpret",
         _time(lambda *a: miss_round(*a, interpret=True), *miss_in),
         f"lanes={M};fuses=3_probes+grant+2_installs")

    def unfused(*a):
        out = ref.miss_round_ref(*a)
        p1 = lease_probe(a[0], a[1], a[7], a[9], a[7], a[7], interpret=True)
        p2 = lease_probe(a[2], a[3], a[8], a[9], a[8], a[8], interpret=True)
        return out, p1, p2
    emit("kernel/miss_round_unfused",
         _time(unfused, *miss_in), "oracle+2_lease_probe_launches")
    wg_in = (r(-1, 50, M, C), r(0, 60000, M, C), r(0, 99, M, C),
             r(0, 50, M), jnp.full((M,), 5, jnp.int32))
    emit("kernel/write_grant_interpret",
         _time(lambda *a: write_grant(*a, interpret=True), *wg_in),
         f"lanes={M};fuses=probe+lex_victim+mm_write")


if __name__ == "__main__":
    main()
