"""Coherence-fabric benchmark: hit-rate and traffic vs. rd_lease/wr_lease.

Drives the sharded TSU service (repro.coherence.fabric) with three host-side
workloads and reports the full FabricStats block per scenario per lease
setting — the production-path counterpart of the simulator's Fig. 7/8 sweeps
(same counter names, so rows are directly comparable):

  shared_prefix  — multi-node serving: replicas re-read a hot set of prefix
                   blocks; a writer occasionally republishes (model refresh).
  local_sgd      — training: W workers read their param blocks each step and
                   write through once per wr_lease-step window, with a fence
                   at the window boundary (the all-reduce).
  mixed_churn    — 50/50 read-write over a key space larger than the caches:
                   worst case for lease reuse, stresses victim-way eviction.

    PYTHONPATH=src python benchmarks/fabric_bench.py [--ops 4000] [--json PATH]

Runs on CPU in well under 60 s; emits JSON to stdout and benchmarks/artifacts.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))

from repro.coherence.fabric import (FabricConfig, ReplicaCache,  # noqa: E402
                                    SharedCache, TSUFabric)

ART = pathlib.Path(__file__).resolve().parent / "artifacts"

LEASE_GRID = [(2, 2), (8, 4), (32, 16)]


def build(rd, wr, *, n_nodes=2, replicas_per_node=2, n_shards=4,
          max_in_flight=8):
    fabric = TSUFabric(FabricConfig(n_shards=n_shards, rd_lease=rd,
                                    wr_lease=wr, max_in_flight=max_in_flight))
    nodes = [SharedCache(fabric, node_id=i) for i in range(n_nodes)]
    replicas = [ReplicaCache(nodes[i]) for i in range(n_nodes)
                for _ in range(replicas_per_node)]
    return fabric, nodes, replicas


def scenario_shared_prefix(rd, wr, ops):
    """Hot prefix blocks read by every replica; periodic republish."""
    fabric, nodes, replicas = build(rd, wr)
    rng = np.random.default_rng(0)
    hot = [f"prefix/{i}" for i in range(16)]
    writer = replicas[0]
    for k in hot:
        writer.put(k, f"{k}@0")
    for t in range(ops):
        r = replicas[int(rng.integers(len(replicas)))]
        k = hot[int(rng.zipf(1.5)) % len(hot)]
        r.get(k)
        if t % 200 == 199:                 # model refresh: republish one block
            writer.put(hot[int(rng.integers(len(hot)))], f"v@{t}")
        if t % 500 == 499:                 # periodic reader sync point
            fabric.barrier()
    return fabric


def scenario_local_sgd(rd, wr, ops):
    """Each worker reads its param blocks every step; write-through + fence
    once per wr_lease-step window (the paper's lease-synced local SGD)."""
    fabric, nodes, replicas = build(rd, wr)
    params = [f"param/{i}" for i in range(8)]
    for k in params:
        replicas[0].put(k, 0)
    fabric.barrier()
    steps = max(1, ops // (len(replicas) * len(params)))
    for step in range(steps):
        for w, r in enumerate(replicas):
            for k in params:
                r.get(k)
        if (step + 1) % wr == 0:           # window boundary: all-reduce
            for w, r in enumerate(replicas):
                for k in params:
                    r.put(k, step)
            fabric.barrier()
    return fabric


def scenario_mixed_churn(rd, wr, ops):
    """Uniform 50/50 read-write over a key space bigger than the caches."""
    fabric, nodes, replicas = build(rd, wr)
    rng = np.random.default_rng(1)
    keys = [f"blk/{i}" for i in range(512)]
    for k in keys[::8]:
        replicas[0].put(k, 0)
    for t in range(ops):
        r = replicas[int(rng.integers(len(replicas)))]
        k = keys[int(rng.integers(len(keys)))]
        if rng.random() < 0.5:
            r.get(k)
        else:
            r.put(k, t)
    fabric.barrier()
    return fabric


SCENARIOS = {
    "shared_prefix": scenario_shared_prefix,
    "local_sgd": scenario_local_sgd,
    "mixed_churn": scenario_mixed_churn,
}


def summarize(stats):
    d = stats.to_dict()
    lookups = d["l1_hits"] + d["l1_to_l2"]
    d["hit_rate_l1"] = round(d["l1_hits"] / max(lookups, 1), 4)
    d["mm_traffic_per_op"] = round(
        d["l2_to_mm"] / max(d["reads"] + d["writes"], 1), 4)
    return d


def run(force: bool = False) -> None:
    """Harness entry point (benchmarks.run): cached sweep + CSV rows."""
    from benchmarks import common

    def compute():
        out = {}
        for name, fn in SCENARIOS.items():
            out[name] = {}
            for rd, wr in LEASE_GRID:
                t0 = time.time()
                fabric = fn(rd, wr, 4000)
                row = summarize(fabric.stats)
                row["wall_us"] = (time.time() - t0) * 1e6
                out[name][f"rd{rd}_wr{wr}"] = row
        return out

    out = common.cached("fabric_bench_suite", compute, force=force)
    for name, grid in out.items():
        if name.startswith("_"):
            continue
        for lease, row in grid.items():
            common.emit(f"fabric/{name}/{lease}", row.get("wall_us", 0.0),
                        f"l1_hit={row['hit_rate_l1']};"
                        f"mm_per_op={row['mm_traffic_per_op']};"
                        f"inval={row['inval_msgs']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ops", type=int, default=4000,
                    help="approximate client ops per scenario")
    ap.add_argument("--json", type=pathlib.Path,
                    default=ART / "fabric_bench.json")
    args = ap.parse_args()

    t0 = time.time()
    out = {}
    for name, fn in SCENARIOS.items():
        out[name] = {}
        for rd, wr in LEASE_GRID:
            fabric = fn(rd, wr, args.ops)
            row = summarize(fabric.stats)
            out[name][f"rd{rd}_wr{wr}"] = row
            print(f"{name:14s} rd={rd:3d} wr={wr:3d} "
                  f"l1_hit={row['hit_rate_l1']:.3f} "
                  f"mm/op={row['mm_traffic_per_op']:.3f} "
                  f"inval={row['inval_msgs']} "
                  f"self_inval={row['self_invalidations']}", flush=True)
    out["_meta"] = {"ops": args.ops, "lease_grid": LEASE_GRID,
                    "wall_s": round(time.time() - t0, 2)}
    args.json.parent.mkdir(parents=True, exist_ok=True)
    args.json.write_text(json.dumps(out, indent=1))
    print(json.dumps(out["_meta"]))
    print(f"wrote {args.json}")


if __name__ == "__main__":
    main()
