"""HALCONE protocol walkthrough: the paper's Fig.5 litmus scenarios with the
event-by-event read results and final logical clocks.

    PYTHONPATH=src python examples/protocol_demo.py
"""
import numpy as np

from repro.core import simulate, sm_wt_halcone, traces


def show(title, cfg, ops, addrs, cus):
    r = simulate(cfg, ops, addrs)
    print(f"\n== {title} ==")
    for cu in cus:
        log = np.asarray(r["read_log"][cu])
        print(f"  CU{cu}: ops={list(np.asarray(ops[cu]))} "
              f"reads->versions={list(log)}")
    print(f"  final L1 cts: {list(np.asarray(r['state'].l1_cts))}")
    print(f"  counters: l1_to_l2={float(r['counters']['l1_to_l2']):.0f} "
          f"l2_to_mm={float(r['counters']['l2_to_mm']):.0f} "
          f"coh_miss_l1={float(r['counters']['coh_miss_l1']):.0f}")


def main():
    cfg = sm_wt_halcone(n_gpus=2, cus_per_gpu=2)
    ops, addrs = traces.litmus_intra(cfg)
    show("Fig 5(a) intra-GPU: CU0/CU1 of GPU0", cfg, ops, addrs, [0, 1])
    print("  -> I0-3 reads the OLD value (read-in-the-past);"
          " I1-3 coherency-misses and sees the write.")
    ops, addrs = traces.litmus_inter(cfg)
    show("Fig 5(b) inter-GPU: GPU0 vs GPU1", cfg, ops, addrs, [0, 2])
    print("  -> the final read on GPU1 refetches from shared MM: coherent"
          " with zero invalidation traffic.")


if __name__ == "__main__":
    main()
