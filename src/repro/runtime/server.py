"""Batched serving runtime on the array-native coherence fabric.

Requests are grouped into fixed-size decode batches; shared prompt prefixes
live in the lease-coherent prefix cache (HALCONE semantics: reuse without
revalidation while the lease is live).  Since the array-native refactor
(DESIGN.md §7) the server issues ONE batched lease probe per serve call —
all groups' prefix keys go through ``BatchedKVLease.get_batch`` (a single
vectorized ``state.tier_probe`` on the steady state), the missing prefixes
are prefilled once, and ONE ``put_batch`` posts their write-throughs.
Since the batched grant pipeline (DESIGN.md §9) the MISS subset is also
vectorized — one batched TSU grant + one batched fill per tier, so a
miss-heavy serve call costs O(1) grant collectives on the sharded fabric
instead of one per missing prefix.  The write side is batched the same
way (DESIGN.md §11): the per-serve ``put_batch`` runs the fabric's
vectorized write pass, so a republish storm posts its write-throughs with
ONE packed collective per batch instead of one per posted write.
``fabric_stats["fast_read_batches"]`` counts the serve calls the replica
tier absorbed entirely; ``fabric_stats["write_batches"]`` counts the
posted-write batch boundaries.
There is no per-key host-object path left: every lease comes from a
``FabricBackend`` (default ``default_fabric()`` — the mesh-placed
``ShardedArrayFabric`` whenever the process sees more than one device, so
TSU shards execute grants on their owning devices and cross-shard traffic
is real collective hops) — pass a shared backend to run many Server
replicas against one sharded TSU service.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.coherence.fabric import FabricBackend, FabricConfig, default_fabric
from repro.coherence.kv_lease import BatchedKVLease
from repro.models import decode_step, init_cache, prefill
from repro.obs import trace as obs
from repro.sharding import NOSHARD


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new: int = 8


def _prefix_key(tokens: np.ndarray) -> str:
    return hashlib.sha1(tokens.tobytes()).hexdigest()[:16]


class Server:
    def __init__(self, cfg, params, *, batch_size: int = 4,
                 max_len: int = 128,
                 fabric: Optional[FabricBackend] = None, replica: int = 0,
                 pipeline: Optional[str] = None):
        # pipeline= applies only when the server builds its own fabric; an
        # explicit fabric already carries its pipeline (conflict = error)
        if fabric is not None and pipeline is not None:
            raise ValueError(
                "pipeline= only applies when Server builds its own fabric; "
                "construct the fabric with pipeline=... instead")
        self.cfg, self.params = cfg, params
        self.B, self.max_len = batch_size, max_len
        self.fabric = fabric if fabric is not None else default_fabric(
            FabricConfig(), pipeline=pipeline or "batched")
        self.kv = BatchedKVLease(self.fabric, replica=replica)
        self._prefill = jax.jit(
            lambda p, c, t: prefill(cfg, p, t, c, ctx=NOSHARD))
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(cfg, p, c, t, pos, ctx=NOSHARD))

    def _prefill_misses(self, keys: List[str], prompts_by_key: Dict[str, np.ndarray],
                        leases: List) -> Dict[str, tuple]:
        """Prefill every missed prefix once; post ONE batched write-through."""
        filled: Dict[str, tuple] = {}
        with obs.span("serve.prefill", cat="serve"):
            for key, hit in zip(keys, leases):
                if hit is None and key not in filled:
                    prompts = prompts_by_key[key]
                    cache = init_cache(self.cfg, prompts.shape[0],
                                       self.max_len)
                    first, cache = self._prefill(self.params, cache,
                                                 jnp.asarray(prompts))
                    obs.fence(first, "serve.prefill.device")
                    filled[key] = (cache, first)
        if filled:
            with obs.span("serve.put_batch", cat="serve",
                          n_filled=len(filled)):
                self.kv.put_batch(list(filled.items()))
        return filled

    def serve(self, requests: List[Request]) -> Dict[int, np.ndarray]:
        with obs.span("serve", cat="serve", n_requests=len(requests)):
            return self._serve(requests)

    def _group_wave(self, requests: List[Request]):
        """Group a wave into decode batches (pad the last one) and
        dispatch its batched lease probe asynchronously: on the sharded
        fabric the probe, miss pass and the next grant exchange are in
        flight when this returns (``kv.get_batch_async``)."""
        with obs.span("serve.group", cat="serve"):
            groups: List[List[Request]] = []
            for i in range(0, len(requests), self.B):
                group = requests[i:i + self.B]
                while len(group) < self.B:
                    group.append(Request(rid=-1, prompt=group[0].prompt))
                groups.append(group)
            prompts = [np.stack([g.prompt for g in group])
                       for group in groups]
            keys = [_prefix_key(p) for p in prompts]
        with obs.span("serve.lease_probe", cat="serve", n_groups=len(keys)):
            uniq = list(dict.fromkeys(keys))
            handle = self.kv.get_batch_async(uniq)
        return groups, prompts, keys, uniq, handle

    def _resolve_and_prefill(self, keys, prompts, uniq, handle):
        """Resolve the wave's probe handle (decode the already-dispatched
        device work) and prefill + post the missed prefixes.  Must run
        before the next wave's probe dispatch — the fabric's handle
        ordering contract (resolve before the next write/fence)."""
        with obs.span("serve.lease_resolve", cat="serve"):
            leases_u = dict(zip(uniq, handle.result()))
            leases = [leases_u[k] for k in keys]
        filled = self._prefill_misses(keys, dict(zip(keys, prompts)), leases)
        return leases, filled

    def _decode_groups(self, groups, prompts, keys, leases,
                       filled) -> Dict[int, np.ndarray]:
        out: Dict[int, np.ndarray] = {}
        with obs.span("serve.decode", cat="serve"):
            for group, pr, key, hit in zip(groups, prompts, keys, leases):
                cache, nxt = hit[0] if hit is not None else filled[key]
                S = pr.shape[1]
                toks = [np.asarray(nxt)]
                max_new = max(g.max_new for g in group)
                for t in range(max_new - 1):
                    nxt, cache = self._decode(self.params, cache,
                                              nxt[:, None], jnp.int32(S + t))
                    toks.append(np.asarray(nxt))
                gen = np.stack(toks, 1)                # [B, max_new]
                for j, g in enumerate(group):
                    if g.rid >= 0:
                        out[g.rid] = gen[j, :g.max_new]
        return out

    def _serve(self, requests: List[Request]) -> Dict[int, np.ndarray]:
        groups, prompts, keys, uniq, handle = self._group_wave(requests)
        leases, filled = self._resolve_and_prefill(keys, prompts, uniq,
                                                   handle)
        return self._decode_groups(groups, prompts, keys, leases, filled)

    def serve_stream(self, waves) -> Dict[int, np.ndarray]:
        """Pipelined serving over an iterable of request waves — the
        overlapped grant-exchange boundary (ISSUE 8 tentpole, DESIGN.md
        §12a).

        For each wave the schedule is: resolve wave N's probe handle,
        prefill + post its misses (the write), **dispatch wave N+1's
        batched lease probe**, then run wave N's decode loop — so wave
        N+1's grant exchange and miss pass execute under wave N's decode
        compute instead of serializing in front of it.  A handle is
        outstanding only across the decode loop (no write/fence), which
        satisfies the fabric's read-handle ordering contract, and every
        fabric op still happens in the same order as back-to-back
        ``serve`` calls — results and fabric state are bit-identical to
        the sequential path.
        """
        out: Dict[int, np.ndarray] = {}
        pending = None
        with obs.span("serve_stream", cat="serve"):
            for wave in waves:
                if pending is None:
                    pending = self._group_wave(wave)
                    continue
                groups, prompts, keys, uniq, handle = pending
                leases, filled = self._resolve_and_prefill(
                    keys, prompts, uniq, handle)
                pending = self._group_wave(wave)     # overlaps the decode
                out.update(self._decode_groups(groups, prompts, keys,
                                               leases, filled))
            if pending is not None:
                groups, prompts, keys, uniq, handle = pending
                leases, filled = self._resolve_and_prefill(
                    keys, prompts, uniq, handle)
                out.update(self._decode_groups(groups, prompts, keys,
                                               leases, filled))
        return out

    @property
    def cache_stats(self):
        return dict(self.kv.stats)

    @property
    def fabric_stats(self):
        """Fabric-wide telemetry (engine.COUNTERS names + service extras)."""
        return self.fabric.stats()
