"""End-to-end driver: train a ~25M-param llama-family model for a few hundred
steps with write-through checkpointing, a simulated node failure, and
restart-from-checkpoint.  (--steps 40 for a quick run.)

    PYTHONPATH=src python examples/train_e2e.py --steps 300
"""
import argparse
import dataclasses

import numpy as np

from repro import configs as cfgs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--fail-at", type=int, default=None)
    args = ap.parse_args()

    # ~25M params: a scaled smollm (same family, wider than the smoke config)
    cfg = dataclasses.replace(
        cfgs.SMOKE["smollm-360m"], name="smollm-25m", n_layers=6, d_model=256,
        n_heads=8, n_kv_heads=4, d_head=32, d_ff=704, vocab=8192)
    data = SyntheticLM(cfg, DataConfig(global_batch=8, seq_len=128))
    trainer = Trainer(cfg, make_host_mesh(),
                      tcfg=TrainerConfig(total_steps=args.steps,
                                         ckpt_period=max(args.steps // 6, 10),
                                         ckpt_dir="/tmp/repro_e2e"),
                      data=data)
    fail_at = args.fail_at if args.fail_at is not None else args.steps // 2
    try:
        out = trainer.run(fail_at=fail_at)
    except RuntimeError as e:
        print(f"[fault] {e} -> restarting from checkpoint")
        out = trainer.resume()
    ls = out["losses"]
    print(f"finished at step {out['final_step']}; loss {ls[0]:.3f} -> "
          f"{np.mean(ls[-10:]):.3f} (mean of last 10)")
    print("events:", out["events"])
    assert np.mean(ls[-10:]) < ls[0]
    print("OK: end-to-end training with failure+restart")


if __name__ == "__main__":
    main()
