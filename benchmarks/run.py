"""Benchmark harness: one module per paper table/figure.

Usage:  PYTHONPATH=src python -m benchmarks.run [--force] [--only fig7,...]
Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true",
                    help="recompute instead of using cached artifacts")
    ap.add_argument("--only", default="",
                    help="comma-separated subset (fig2,fig7,fig8,fig9,"
                         "lease,kernels,roofline,fabric)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (fabric_bench, fig2_rdma_gap, fig7_speedup,
                            fig8_scaling, fig9_xtreme, kernel_bench,
                            lease_sensitivity, roofline)
    suites = [
        ("fig2", fig2_rdma_gap.main),
        ("fig7", fig7_speedup.main),
        ("fig8", fig8_scaling.main),
        ("fig9", fig9_xtreme.main),
        ("lease", lease_sensitivity.main),
        ("kernels", kernel_bench.main),
        ("roofline", roofline.main),
        ("fabric", fabric_bench.run),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites:
        if only and name not in only:
            continue
        try:
            fn(force=args.force)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
