"""Coherence fabric: the sharded TSU service behind every lease in the repo.

Layout (DESIGN.md §3, §7):
  backend.py — FabricBackend: the one lease API; HostFabric = the
               host-object oracle behind it
  arrays.py  — ArrayFabric: the array-native production backend (state as
               core.state pytrees, ops applied as one jitted scan);
               ShardedArrayFabric: the same scan as a shard_map body with
               TSU shards placed along the "fabric" mesh axis (DESIGN.md
               §8); default_fabric(): picks between them by device count
  pipeline.py— the batched grant pipeline (DESIGN.md §9): the vectorized
               read_batch miss pass (conflict-free rounds over
               state.tsu_lease_batch), plus the jaxpr collective counter
               the O(1)-collectives-per-batch pin is built on
  tsu.py     — TSUShard / TSUFabric: the host MM+TSU authority
  cache.py   — ReplicaCache over SharedCache: the host L1-over-L2 tiers
  writeq.py  — WriteQueue: bounded posted write-throughs + fence
  stats.py   — FabricStats: the engine.COUNTERS-compatible telemetry block

`repro.coherence.kv_lease` (serving) and `repro.coherence.lease_sync`
(training) are thin adapters over the backend; the hierarchy simulator
(`repro.core.engine`) is the same protocol run under a timing model, and
both import their transition rules from `repro.core.state`.
"""
from repro.coherence.fabric.arrays import (ArrayFabric,  # noqa: F401
                                           ShardedArrayFabric,
                                           default_fabric)
from repro.coherence.fabric.backend import (FabricBackend,  # noqa: F401
                                            HostFabric, Op,
                                            ReadBatchHandle)
from repro.coherence.fabric.cache import ReplicaCache, SharedCache  # noqa: F401
from repro.coherence.fabric.stats import FabricStats  # noqa: F401
from repro.coherence.fabric.tsu import (FabricConfig, LeaseGrant,  # noqa: F401
                                        TSUFabric, TSUShard, stable_hash)
from repro.coherence.fabric.writeq import WriteQueue  # noqa: F401
