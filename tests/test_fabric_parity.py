"""Backend-parity suite: the array-native fabric is BIT-IDENTICAL to the
host-object fabric (DESIGN.md §7), the mesh-sharded fabric to both
(DESIGN.md §8), and the batched grant pipeline — the vectorized
read_batch miss pass plus the one-collective-per-batch sharded schedule —
to all of the above AND to its own ``pipeline="scan"`` fallback
(DESIGN.md §9), with a structural jaxpr pin that a batch issues O(1)
grant collectives rather than one per op.

Randomized op traces (reads/writes/fences/authority ops across replicas,
including forced 16-bit overflow reinits and TSU victim evictions) are
applied to both ``FabricBackend`` implementations; every observable must
match exactly: per-op results (values + versions), the ordered MM grant
log (wts/rts/version), the full FabricStats block (including the Fig-10
per-link byte counters), each replica's mirror counters, and the per-key
``memts`` clocks.  A hypothesis layer fuzzes the same property when
hypothesis is installed (CI does; the ``[test]`` extra pulls it in).

``ShardedArrayFabric`` runs the same suite on a REAL multi-device mesh:
the ``test_sharded_parity_forced_8_devices`` harness re-launches this
module's ``_sharded_multidevice_check`` in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (or runs it
in-process when the session already has 8+ devices, as CI's forced-mesh
job does), pinning sharded-vs-host AND sharded-vs-single-device equality
with one TSU shard per device and grants travelling over collectives.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.coherence.fabric import (ArrayFabric, FabricConfig, HostFabric,
                                    Op, ShardedArrayFabric)
from repro.core import protocol
from repro.core.state import BLOCK_BYTES

# one small geometry reused everywhere so the jitted op-scan compiles once
SMALL = dict(n_shards=2, rd_lease=8, wr_lease=4, tsu_capacity=4,
             shared_sets=4, shared_ways=2, replica_sets=2, replica_ways=2,
             max_in_flight=2)
# near-TS_MAX leases + a 2-entry TSU: every few ops trigger the 16-bit
# overflow reinit or a victim eviction
OVERFLOW = dict(n_shards=1, rd_lease=protocol.TS_MAX // 2, wr_lease=20000,
                tsu_capacity=2, shared_sets=2, shared_ways=1,
                replica_sets=1, replica_ways=2, max_in_flight=0)

# roomier tiers: read batches over these rarely collide on a set, so the
# batched pipeline's miss pass runs genuinely vectorized rounds (SMALL's
# 2-set replica tier shreds batches into near-sequential rounds and mostly
# exercises the op-scan fallback instead — both paths must stay exact)
MEDIUM = dict(n_shards=4, rd_lease=8, wr_lease=4, tsu_capacity=64,
              shared_sets=64, shared_ways=4, replica_sets=32,
              replica_ways=2, max_in_flight=4)

KEYS = [f"k{i}" for i in range(8)]


def random_trace(rng, n_ops, n_replicas, wr_choices=(None,), n_nodes=2):
    ops = []
    for t in range(n_ops):
        r = int(rng.integers(n_replicas))
        k = KEYS[int(rng.integers(len(KEYS)))]
        c = rng.random()
        wl = wr_choices[int(rng.integers(len(wr_choices)))]
        if c < 0.45:
            ops.append(Op("read", k, replica=r))
        elif c < 0.8:
            ops.append(Op("write", k, f"v{t}", replica=r, wr_lease=wl))
        elif c < 0.85:
            ops.append(Op("fence"))
        elif c < 0.9:
            ops.append(Op("mm_write", k, f"m{t}", wr_lease=wl))
        elif c < 0.95:
            ops.append(Op("publish", k, f"p{t}",
                          node=int(rng.integers(n_nodes))))
        else:
            ops.append(Op("mm_read", k))
    return ops


def build_pair(cfg_kw, n_nodes=2, replicas_per_node=2):
    cfg = FabricConfig(**cfg_kw)
    return (HostFabric(cfg, n_nodes=n_nodes,
                       replicas_per_node=replicas_per_node),
            ArrayFabric(cfg, n_nodes=n_nodes,
                        replicas_per_node=replicas_per_node))


def build_triple(cfg_kw, n_nodes=2, replicas_per_node=2):
    """host oracle + batched-pipeline array + scan-pipeline array."""
    cfg = FabricConfig(**cfg_kw)
    mk = lambda **kw: ArrayFabric(cfg, n_nodes=n_nodes,
                                  replicas_per_node=replicas_per_node, **kw)
    return (HostFabric(cfg, n_nodes=n_nodes,
                       replicas_per_node=replicas_per_node),
            mk(pipeline="batched"), mk(pipeline="scan"))


def assert_equivalent(host, arr, ops):
    hres = host.apply(ops)
    ares = arr.apply(ops)
    for i, ((op, hr), (_, ar)) in enumerate(zip(hres, ares)):
        assert hr == ar, f"op {i} ({op.kind} {op.key!r}): {hr!r} != {ar!r}"
    assert host.grant_log == arr.grant_log, "MM grant logs diverged"
    assert host.stats() == arr.stats(), "FabricStats diverged"
    for r in range(host.n_replicas):
        assert host.replica_stats(r) == arr.replica_stats(r), \
            f"replica {r} mirror counters diverged"
    for k in KEYS:
        assert host.memts(k) == arr.memts(k), f"memts({k!r}) diverged"


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_differential_random_trace(seed):
    host, arr = build_pair(SMALL)
    ops = random_trace(np.random.default_rng(seed), 350, 4)
    assert_equivalent(host, arr, ops)


@pytest.mark.parametrize("seed", [0, 1])
def test_differential_overflow_reinit_and_tsu_eviction(seed):
    """Forced 16-bit wraps + constant victim eviction in a 2-entry TSU."""
    host, arr = build_pair(OVERFLOW, n_nodes=1, replicas_per_node=2)
    ops = random_trace(np.random.default_rng(seed), 250, 2,
                       wr_choices=(None, 1, 30000), n_nodes=1)
    assert_equivalent(host, arr, ops)
    assert host.stats()["overflow_reinits"] > 0, "overflow never triggered"
    assert host.stats()["tsu_evictions"] > 0, "eviction never triggered"


def test_read_batch_two_phase_parity():
    """The batched read contract (hits vectorized first, misses in order)
    produces identical results, stats and mirrors on both backends."""
    host, arr = build_pair(SMALL)
    rng = np.random.default_rng(7)
    warm = random_trace(rng, 120, 4)
    host.apply(warm)
    arr.apply(warm)
    batch = [KEYS[int(rng.integers(len(KEYS)))] for _ in range(32)]
    batch.append("never-written")       # unknown key exercises phase 2
    assert host.read_batch(batch, replica=1) == arr.read_batch(batch,
                                                               replica=1)
    assert host.stats() == arr.stats()
    assert host.replica_stats(1) == arr.replica_stats(1)


def test_fast_path_equals_scan_path_on_all_hit_batch():
    """Phase 1 (one vectorized tier_probe) is bit-identical to the op-scan
    on an all-hit batch — results, counters, and the full device state."""
    import jax

    a1 = ArrayFabric(FabricConfig(**SMALL), n_nodes=1, replicas_per_node=1)
    a2 = ArrayFabric(FabricConfig(**SMALL), n_nodes=1, replicas_per_node=1)
    keys = KEYS[:4]
    for b in (a1, a2):
        for k in keys:
            b.write(k, f"{k}@0")
        b.fence()
    r1 = a1.read_batch(keys)                                  # fast path
    r2 = [x for _, x in a2.apply([Op("read", k) for k in keys])]
    assert r1 == r2
    assert a1.fast_read_batches == 1
    s1, s2 = a1.stats(), a2.stats()
    # the all-hit batch is itself counted (FabricStats field); raw apply
    # is not a read_batch call, so it legitimately records none
    assert (s1.pop("fast_read_batches"), s2.pop("fast_read_batches")) == (1, 0)
    assert s1 == s2
    for x, y in zip(jax.tree_util.tree_leaves(a1._af),
                    jax.tree_util.tree_leaves(a2._af)):
        assert (np.asarray(x) == np.asarray(y)).all()


# ------------------------------------------------- batched grant pipeline
def _drive_read_batches(backends, seed, n_calls=6, batch=24):
    """Interleave randomized mixed hit/miss/dup read batches with writes
    and fences on every backend; returns the per-call results."""
    outs = [[] for _ in backends]
    rng = np.random.default_rng(seed)
    for c in range(n_calls):
        ks = [KEYS[int(rng.integers(len(KEYS)))] for _ in range(batch)]
        ks.append(f"fresh{c}")              # unknown key: compulsory miss
        rep = int(rng.integers(backends[0].n_replicas))
        for o, b in zip(outs, backends):
            o.append(b.read_batch(ks, replica=rep))
        wk = KEYS[int(rng.integers(len(KEYS)))]
        for b in backends:                  # expire leases between calls
            b.write(wk, f"w{seed}.{c}", replica=0)
            if c % 2:
                b.fence()
    return outs


def assert_state_equal(a, b):
    import jax

    for x, y in zip(jax.tree_util.tree_leaves(a._af),
                    jax.tree_util.tree_leaves(b._af)):
        assert (np.asarray(jax.device_get(x))
                == np.asarray(jax.device_get(y))).all()


@pytest.mark.parametrize("seed,cfg_kw", [(0, SMALL), (1, SMALL), (2, SMALL),
                                         (0, MEDIUM), (1, MEDIUM)])
def test_batched_pipeline_mixed_batch_parity(seed, cfg_kw):
    """The tentpole pin: the vectorized miss pass (pipeline="batched") is
    bit-identical to the scan pipeline AND the host oracle on randomized
    mixed hit/miss/write/fence batches — per-op results, ordered grant
    log, FabricStats, replica mirrors, memts, and the full device state
    of batched-vs-scan.  SMALL mostly stresses the conflict-round
    fallback; MEDIUM runs real multi-op vectorized rounds."""
    host, batched, scan = build_triple(cfg_kw)
    warm = random_trace(np.random.default_rng(seed + 100), 150, 4)
    for b in (host, batched, scan):
        b.apply(warm)
    oh, ob, os_ = _drive_read_batches((host, batched, scan), seed)
    assert oh == ob, "batched pipeline diverged from the host oracle"
    assert oh == os_, "scan pipeline diverged from the host oracle"
    assert host.stats() == batched.stats() == scan.stats()
    assert list(host.grant_log) == list(batched.grant_log) \
        == list(scan.grant_log)
    for r in range(host.n_replicas):
        assert host.replica_stats(r) == batched.replica_stats(r) \
            == scan.replica_stats(r)
    for k in KEYS:
        assert host.memts(k) == batched.memts(k) == scan.memts(k)
    assert_state_equal(batched, scan)


def test_batched_grant_overflow_reinit_and_tsu_eviction():
    """Forced 16-bit overflow reinits INSIDE the vectorized miss pass
    (state.tsu_lease_batch's reinit branch) and TSU victim evictions
    inside batched write-throughs, bit-identical across host / batched /
    scan.  Two write rounds at wr_lease=30000 push memts to ~60000, so a
    fresh replica's read grant (rd_lease=TS_MAX//2) must wrap; the
    2-entry-TSU config forces victim eviction on every allocation."""
    ov = dict(OVERFLOW, tsu_capacity=4, rd_lease=protocol.TS_MAX // 2)
    host, batched, scan = build_triple(ov, n_nodes=1, replicas_per_node=2)
    for b in (host, batched, scan):
        for rnd in range(2):
            b.write_batch([(k, f"{k}@{rnd}") for k in KEYS[:4]],
                          replica=0, wr_lease=30000)
            b.fence()
    ks = KEYS[:4] + KEYS[:2]                # dups exercise conflict rounds
    rh = host.read_batch(ks, replica=1)
    assert rh == batched.read_batch(ks, replica=1)
    assert rh == scan.read_batch(ks, replica=1)
    assert host.stats() == batched.stats() == scan.stats()
    assert list(host.grant_log) == list(batched.grant_log)
    assert host.stats()["overflow_reinits"] > 0, \
        "the batched grant never hit the reinit branch"
    assert_state_equal(batched, scan)

    # tiny TSU: victim evictions inside the batched write-throughs
    host2, batched2, scan2 = build_triple(OVERFLOW, n_nodes=1,
                                          replicas_per_node=2)
    for b in (host2, batched2, scan2):
        b.write_batch([(k, f"{k}@e") for k in KEYS], replica=0)
        b.fence()
    rh2 = host2.read_batch(KEYS, replica=1)
    assert rh2 == batched2.read_batch(KEYS, replica=1)
    assert rh2 == scan2.read_batch(KEYS, replica=1)
    assert host2.stats() == batched2.stats() == scan2.stats()
    assert host2.stats()["tsu_evictions"] > 0, "eviction never triggered"


def test_fast_read_batches_in_stats():
    """Satellite pin: the all-hit-batch counter lives in the stats block
    on BOTH backends, so the existing stats-equality assertions cover it."""
    host, arr = build_pair(SMALL)
    for b in (host, arr):
        b.write_batch([(k, f"{k}@0") for k in KEYS[:4]], replica=1)
        b.fence()
        b.read_batch(KEYS[:4], replica=1)       # fill the replica tier
        b.read_batch(KEYS[:4], replica=1)       # pure lease-hit batch
    assert host.stats()["fast_read_batches"] == \
        arr.stats()["fast_read_batches"] > 0
    assert host.stats() == arr.stats()
    assert arr.fast_read_batches == arr.stats()["fast_read_batches"]


def test_batched_pipeline_one_collective_per_batch():
    """The acceptance pin: under pipeline="batched" a sharded batch of B
    ops issues O(1) grant collectives — ONE packed all_gather in the
    dedicated grant-exchange program (``_gather_run``) and NONE in the
    op-scan or the miss pass (the dev0 pass engine's programs are
    collective-free) — while pipeline="scan" keeps its per-scan-step
    collective.  Counted structurally in the jaxpr, so the pin holds on
    any mesh size (the collective executes once per batch regardless of
    B)."""
    import jax
    import jax.numpy as jnp

    from repro.coherence.fabric.pipeline import collective_counts

    cfg = FabricConfig(**SMALL)
    xs = {k: jnp.zeros((8,), jnp.int32) for k in
          ("kind", "rep", "node", "key", "set1", "set2", "shard", "wl")}
    rd = wr = jnp.int32(8)

    counts = {}
    for pipe in ("batched", "scan"):
        fab = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2,
                                 pipeline=pipe)
        af = fab._af
        jx = jax.make_jaxpr(fab._run)(af, xs, rd, wr)
        counts[pipe] = collective_counts(jx)
        if pipe == "batched":
            jg = jax.make_jaxpr(fab._gather_run)(
                af.tsu, af.tsu_ver, af.tsu_gseq, af.tsu_seq, af.tsu_nseq)
            counts["gather"] = collective_counts(jg)
            jm = jax.make_jaxpr(fab._miss_run)(
                af, jnp.zeros((4, 8), jnp.int32),
                jnp.zeros((4, 8), bool), jnp.int32(1), jnp.int32(0),
                rd, wr)
            counts["miss_pass"] = collective_counts(jm)
    assert counts["gather"] == {"total": 1, "in_loop": 0}, counts
    assert counts["batched"] == {"total": 0, "in_loop": 0}, counts
    assert counts["miss_pass"] == {"total": 0, "in_loop": 0}, counts
    assert counts["scan"]["in_loop"] >= 1, counts       # O(B) collectives


# ------------------------------------------------------- sharded fabric
def test_sharded_fabric_parity_on_host_mesh():
    """ShardedArrayFabric is a FabricBackend and bit-identical to the host
    oracle through the shard_map entry point on whatever mesh this host
    has (1 device here; the 8-device variant runs in a subprocess)."""
    cfg = FabricConfig(**SMALL)
    host = HostFabric(cfg, n_nodes=2, replicas_per_node=2)
    sh = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    assert cfg.n_shards % sh.n_shard_devices == 0
    ops = random_trace(np.random.default_rng(3), 200, 4)
    assert_equivalent(host, sh, ops)


def test_sharded_rejects_indivisible_mesh():
    from repro.launch.mesh import make_fabric_mesh
    mesh = make_fabric_mesh()                      # all devices, 1 axis
    if int(mesh.devices.size) == 1:
        pytest.skip("single-device mesh divides everything")
    with pytest.raises(ValueError, match="divisible"):
        ShardedArrayFabric(FabricConfig(
            n_shards=int(mesh.devices.size) + 1, tsu_capacity=4), mesh=mesh)


def _keys_by_shard(cfg, want, prefix="t"):
    """First key hashing to each wanted shard (stable_hash routing)."""
    from repro.coherence.fabric import stable_hash
    out = {}
    i = 0
    while len(out) < len(want):
        k = f"{prefix}{i}"
        s = stable_hash(k) % cfg.n_shards
        if s in want and s not in out:
            out[s] = k
        i += 1
    return out


def test_cross_shard_reads_count_inter_gpu_bytes():
    """The Fig-10 pin: an MM access routed to a NON-home TSU shard moves
    BLOCK_BYTES over the inter-GPU link; a home-shard access moves none —
    and both backends account it identically."""
    cfg = FabricConfig(n_shards=2, tsu_capacity=8)
    by_shard = _keys_by_shard(cfg, {0, 1})
    for fab in (HostFabric(cfg, n_nodes=1, replicas_per_node=1),
                ArrayFabric(cfg, n_nodes=1, replicas_per_node=1)):
        # node 0's home shard is 0 (node_id % n_shards)
        fab.mm_write(by_shard[0], "local")         # authority preload
        fab.mm_write(by_shard[1], "remote")
        base = fab.stats()["bytes_inter_gpu"]
        assert fab.read(by_shard[0], replica=0) is not None
        assert fab.stats()["bytes_inter_gpu"] == base, \
            "shard-local read must not touch the inter-GPU link"
        assert fab.read(by_shard[1], replica=0) is not None
        assert fab.stats()["bytes_inter_gpu"] == base + BLOCK_BYTES, \
            "cross-shard read must move exactly one block inter-GPU"
        st = fab.stats()
        assert st["bytes_l1_l2"] == st["l1_to_l2"] * BLOCK_BYTES
        assert st["bytes_l2_mm"] == st["l2_to_mm"] * BLOCK_BYTES
        assert st["bytes_inter_gpu"] == st["pcie_blocks"] * BLOCK_BYTES
        assert st["inval_msgs"] == 0               # the paper's claim


def _sharded_multidevice_check():
    """Body of the forced-8-device parity check (run in-process when the
    session already has >= 8 devices, else via the subprocess harness):
    ShardedArrayFabric-vs-HostFabric and sharded-vs-single-device equality
    — results, grant log, stats incl. traffic counters, replica mirrors —
    with one TSU shard per device, plus the overflow/eviction config."""
    import jax

    assert len(jax.devices()) >= 8, "needs the forced 8-device host mesh"
    cfg_kw = dict(SMALL, n_shards=8)
    cfg = FabricConfig(**cfg_kw)
    host = HostFabric(cfg, n_nodes=2, replicas_per_node=2)
    sh = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    assert sh.n_shard_devices == 8                 # one shard per device
    ops = random_trace(np.random.default_rng(11), 220, 4)
    assert_equivalent(host, sh, ops)

    arr = ArrayFabric(cfg, n_nodes=2, replicas_per_node=2)
    arr.apply(ops)
    batch = [KEYS[i % len(KEYS)] for i in range(24)] + ["missing-key"]
    assert sh.read_batch(batch, replica=1) == arr.read_batch(batch,
                                                             replica=1)
    assert sh.stats() == arr.stats()
    assert list(sh.grant_log) == list(arr.grant_log)
    for r in range(sh.n_replicas):
        assert sh.replica_stats(r) == arr.replica_stats(r)
    assert sh.stats()["bytes_inter_gpu"] > 0       # the mesh saw real hops

    # batched grant pipeline vs per-op collective schedule on the REAL
    # mesh: same trace + miss-heavy read batches, everything equal (the
    # default `sh` above already runs pipeline="batched"; this pins it
    # against pipeline="scan" executing one collective per op)
    scan = ShardedArrayFabric(cfg, n_nodes=2, replicas_per_node=2,
                              pipeline="scan")
    assert sh.pipeline == "batched" and scan.pipeline == "scan"
    scan.apply(ops)
    scan.read_batch(batch, replica=1)
    ob, osc = _drive_read_batches((sh, scan), seed=21, n_calls=3)
    assert ob == osc, "batched pipeline diverged from scan on the mesh"
    assert sh.stats() == scan.stats()
    assert list(sh.grant_log) == list(scan.grant_log)

    # overflow reinits + TSU victim evictions through the sharded path
    ocfg = dict(OVERFLOW, n_shards=2)
    host2 = HostFabric(FabricConfig(**ocfg), n_nodes=1, replicas_per_node=2)
    sh2 = ShardedArrayFabric(FabricConfig(**ocfg), n_nodes=1,
                             replicas_per_node=2)
    assert sh2.n_shard_devices == 2
    ops2 = random_trace(np.random.default_rng(12), 150, 2,
                        wr_choices=(None, 1, 30000), n_nodes=1)
    assert_equivalent(host2, sh2, ops2)
    assert host2.stats()["overflow_reinits"] > 0
    return True


def test_sharded_parity_forced_8_devices():
    """Run ``_sharded_multidevice_check`` on an 8-device host mesh: in
    process if this session was launched with the forced flag (CI), else
    in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8."""
    import jax

    if len(jax.devices()) >= 8:
        assert _sharded_multidevice_check()
        return
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        " --xla_force_host_platform_device_count=8").strip()
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(repo, "src"), os.path.join(repo, "tests")]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    proc = subprocess.run(
        [sys.executable, "-c",
         "from test_fabric_parity import _sharded_multidevice_check; "
         "assert _sharded_multidevice_check(); print('SHARDED-PARITY-OK')"],
        env=env, cwd=repo, capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, (
        f"forced-8-device parity subprocess failed:\n--- stdout ---\n"
        f"{proc.stdout}\n--- stderr ---\n{proc.stderr[-4000:]}")
    assert "SHARDED-PARITY-OK" in proc.stdout


def test_single_transition_layer():
    """Acceptance pin: both consumers import the rules from core.state."""
    from repro.coherence.fabric import arrays
    from repro.core import engine, state
    assert engine.S is state
    assert arrays.S is state


# ---------------------------------------------------------------- fuzzing
try:
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                      # CI installs it via the [test] extra
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    _op = st.one_of(
        st.tuples(st.just("read"), st.integers(0, 3),
                  st.sampled_from(KEYS)),
        st.tuples(st.just("write"), st.integers(0, 3),
                  st.sampled_from(KEYS)),
        st.tuples(st.just("fence"), st.just(0), st.just(KEYS[0])),
        st.tuples(st.just("mm_write"), st.just(0), st.sampled_from(KEYS)),
        st.tuples(st.just("publish"), st.integers(0, 1),
                  st.sampled_from(KEYS)),
        st.tuples(st.just("mm_read"), st.just(0), st.sampled_from(KEYS)),
    )

    @settings(max_examples=12, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(_op, min_size=1, max_size=60))
    def test_hypothesis_differential(trace):
        host, arr = build_pair(SMALL)
        ops = []
        for t, (kind, idx, key) in enumerate(trace):
            if kind == "fence":
                ops.append(Op("fence"))
            elif kind == "publish":
                ops.append(Op("publish", key, f"p{t}", node=idx))
            elif kind in ("mm_write", "write"):
                ops.append(Op(kind, key, f"v{t}", replica=idx))
            else:
                ops.append(Op(kind, key, replica=idx))
        assert_equivalent(host, arr, ops)

    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    @given(st.lists(_op, min_size=1, max_size=40),
           st.lists(st.tuples(st.integers(0, 3),
                              st.lists(st.sampled_from(KEYS + ["nk0", "nk1"]),
                                       min_size=1, max_size=20)),
                    min_size=1, max_size=4))
    def test_hypothesis_batched_read_parity(trace, batches):
        """Fuzz the miss-subset ordering contract: a random warm trace,
        then random mixed hit/miss/dup read batches — batched pipeline vs
        scan pipeline vs host, results + stats + grant log all equal."""
        host, batched, scan = build_triple(SMALL)
        ops = [Op("write", key, f"v{t}", replica=idx) if kind == "write"
               else Op("fence") if kind == "fence"
               else Op(kind, key, f"v{t}") if kind in ("mm_write", "publish")
               else Op(kind, key, replica=idx)
               for t, (kind, idx, key) in enumerate(trace)]
        for b in (host, batched, scan):
            b.apply(ops)
        for rep, ks in batches:
            rh = host.read_batch(ks, replica=rep)
            assert rh == batched.read_batch(ks, replica=rep)
            assert rh == scan.read_batch(ks, replica=rep)
        assert host.stats() == batched.stats() == scan.stats()
        assert list(host.grant_log) == list(batched.grant_log)
        assert_state_equal(batched, scan)
else:
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_differential():
        pass

    @pytest.mark.skip(reason="hypothesis not installed")
    def test_hypothesis_batched_read_parity():
        pass
