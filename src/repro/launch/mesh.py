"""Production mesh builders.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the dry-run's 512 placeholder
devices to work while smoke tests/benches still see 1 device.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips ("data","model").
    Multi-pod: 2x16x16 = 512 chips ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever this host has (CPU smoke tests / examples): 1 device mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


def make_fabric_mesh(n_shards=None, devices=None):
    """The coherence fabric's 1-axis ``fabric`` mesh: TSU shard ``s`` lives
    on device ``s // (n_shards / D)`` (the paper's one-TSU-per-HBM-stack
    placement; see coherence/fabric/arrays.ShardedArrayFabric).

    Uses the LARGEST device count that divides ``n_shards`` so every
    device owns an equal contiguous run of shards; on a 1-device host this
    degenerates to a single-device mesh (same shard_map entry point)."""
    import numpy as np
    from jax.sharding import Mesh

    devs = list(devices) if devices is not None else list(jax.devices())
    d = len(devs)
    if n_shards is not None:
        while d > 1 and n_shards % d:
            d -= 1
    return Mesh(np.array(devs[:d]), ("fabric",))
