"""System configurations for the paper's five evaluated MGPU systems (§4.1),
as a jax pytree so the config axis is vmappable (DESIGN.md §5).

Modeled systems (Table 1 / §4.1's evaluated set; the name encodes
interconnect - L2 policy - coherence):

  ===================  =========  =========  =============================
  name                 topology   L2 policy  coherence
  ===================  =========  =========  =============================
  RDMA-WB-NC           rdma       wb         none (baseline; explicit h2d
                                             copies, remote L2 over PCIe)
  RDMA-WB-C-HMG        rdma       wb         HMG: VI-style home directory,
                                             writer invalidates sharers
  SM-WB-NC             sm         wb         none (shared memory, no coh.)
  SM-WT-NC             sm         wt         none (the paper's perf target)
  SM-WT-C-HALCONE      sm         wt         HALCONE timestamps (§3)
  ===================  =========  =========  =============================

Geometry is Table 2's real sizes (64 B blocks): per-CU L1 16 KB 4-way
(l1_sets=64), per-GPU L2 256 KB 16-way x 8 banks (l2_sets=256), 8 HBM
stacks, TSU 8-way with 2048 sets per stack.  Latency/bandwidth constants
follow §4.1: PCIe4 32 GB/s/dir links, 1 TB/s aggregate L2<->MM, 100-cycle
MC folded into mm_lat, 50-cycle TSU (accessed in parallel with DRAM -> off
the critical path), 1 GHz clock.  The paper's default leases are
RdLease=10, WrLease=5 (§4.2).

Pytree split (registered below): **meta fields** are structural — they fix
array shapes and traced branch structure (geometry, GPU/CU counts,
topology/policy/protocol strings) and stay Python scalars; **data fields**
are the numeric knobs (leases, latencies, service times, mlp) and become
traced leaves.  Configs that share ``static_key()`` can therefore be
stacked with ``stack_configs`` and swept in one ``jax.vmap`` — a new system
variant along those axes is one config row, not new code (MGPU-TSM's
shared-memory-config argument).  ``core.engine.sweep`` groups mixed-static
configs automatically.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SystemConfig:
    name: str = "SM-WT-C-HALCONE"
    n_gpus: int = 4
    cus_per_gpu: int = 32
    topology: str = "sm"            # sm | rdma
    l2_policy: str = "wt"           # wt | wb
    protocol: str = "halcone"       # none | halcone | hmg
    rd_lease: int = 10
    wr_lease: int = 5
    # geometry (64 B blocks)
    l1_sets: int = 64
    l1_ways: int = 4
    l2_banks: int = 8
    l2_sets: int = 256
    l2_ways: int = 16
    n_hbm: int = 8
    tsu_sets: int = 2048
    tsu_ways: int = 8
    page_blocks: int = 64           # 4 KB pages interleaved across modules
    # latencies (cycles @ 1 GHz)
    l1_lat: float = 4.0
    l2_lat: float = 28.0
    mm_lat: float = 200.0           # incl. the calibrated 100-cycle MC
    tsu_lat: float = 50.0           # parallel with DRAM -> off critical path
    pcie_lat: float = 600.0
    # per-64B-block service times (queuing): cycles/block
    l2_service: float = 6.0         # effective bank occupancy per access
    mm_service: float = 3.0         # row activation + 1TB/s aggregate
    pcie_service: float = 2.0       # 32 GB/s = 32 B/cycle -> 2 cyc/block
    mlp: float = 4.0                # per-CU memory-level parallelism: a CU's
                                    # wavefronts overlap ~4 outstanding misses

    @property
    def n_cus(self) -> int:
        return self.n_gpus * self.cus_per_gpu

    @property
    def coherent(self) -> bool:
        return self.protocol == "halcone"


# Pytree split: meta = structural (shapes / branch structure; must agree for
# two configs to share one vmapped sweep group), data = numeric knobs
# (vmappable axis).  rd/wr leases are data: a lease sweep is one stacked
# config (benchmarks/lease_sensitivity.py drives 6 lease pairs as one
# vmap group of 6).
META_FIELDS = ("name", "n_gpus", "cus_per_gpu", "topology", "l2_policy",
               "protocol", "l1_sets", "l1_ways", "l2_banks", "l2_sets",
               "l2_ways", "n_hbm", "tsu_sets", "tsu_ways", "page_blocks")
DATA_FIELDS = ("rd_lease", "wr_lease", "l1_lat", "l2_lat", "mm_lat",
               "tsu_lat", "pcie_lat", "l2_service", "mm_service",
               "pcie_service", "mlp")

jax.tree_util.register_dataclass(SystemConfig, data_fields=list(DATA_FIELDS),
                                 meta_fields=list(META_FIELDS))


def static_key(cfg: SystemConfig) -> tuple:
    """Hashable structural signature.  Configs with equal keys (ignoring
    ``name``) lower to the same traced round function and may be stacked
    into one vmap group."""
    return tuple(getattr(cfg, f) for f in META_FIELDS if f != "name")


def stack_configs(cfgs) -> SystemConfig:
    """Stack configs sharing static structure into one config whose data
    leaves carry a leading [C] axis (the vmappable config axis)."""
    cfgs = list(cfgs)
    base = static_key(cfgs[0])
    for c in cfgs[1:]:
        if static_key(c) != base:
            raise ValueError(f"config {c.name} has different static "
                             f"structure than {cfgs[0].name}; use "
                             "engine.sweep to mix static groups")
    # name is a meta field: normalize it so the treedefs match under tree_map
    joined = "|".join(c.name for c in cfgs)
    cfgs = [dataclasses.replace(c, name=joined) for c in cfgs]
    return jax.tree_util.tree_map(
        lambda *xs: jnp.stack([jnp.asarray(x) for x in xs]), *cfgs)


def rdma_wb_nc(**kw) -> SystemConfig:
    return SystemConfig(name="RDMA-WB-NC", topology="rdma", l2_policy="wb",
                        protocol="none", **kw)


def rdma_wb_hmg(**kw) -> SystemConfig:
    return SystemConfig(name="RDMA-WB-C-HMG", topology="rdma", l2_policy="wb",
                        protocol="hmg", **kw)


def sm_wb_nc(**kw) -> SystemConfig:
    return SystemConfig(name="SM-WB-NC", topology="sm", l2_policy="wb",
                        protocol="none", **kw)


def sm_wt_nc(**kw) -> SystemConfig:
    return SystemConfig(name="SM-WT-NC", topology="sm", l2_policy="wt",
                        protocol="none", **kw)


def sm_wt_halcone(**kw) -> SystemConfig:
    return SystemConfig(name="SM-WT-C-HALCONE", topology="sm", l2_policy="wt",
                        protocol="halcone", **kw)


ALL_CONFIGS = (rdma_wb_nc, rdma_wb_hmg, sm_wb_nc, sm_wt_nc, sm_wt_halcone)
