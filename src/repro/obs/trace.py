"""Low-overhead host-side span tracer with Chrome-trace export.

The tracer wraps the fabric batch lifecycle phases (DESIGN.md §10 span
taxonomy: ``fabric.pack`` → ``fabric.exchange`` → ``fabric.scan`` /
``fabric.fast_probe`` → ``fabric.miss_pass`` → ``fabric.decode`` →
``fabric.donate``, plus ``serve.*`` and ``engine.sweep.*``) and exports
them as Chrome-trace JSON — openable in ``chrome://tracing`` / Perfetto.

Design constraints, in priority order:

  1. **Disabled is free.**  Tracing is OFF by default; a disabled
     ``span()`` call is one module-global load, one attribute check and a
     ``with`` on a shared no-op singleton — a few hundred nanoseconds
     against batch phases measured in hundreds of microseconds.  The <1%
     overhead gate (tests/test_obs.py, the paper's own bar) pins this:
     spans-per-batch × disabled-span-cost must stay under 1% of the
     batched serving path's per-batch latency.  Disabled tracing also
     never fences: ``fence()`` returns its value untouched, so the
     async-dispatch pipeline is exactly the untraced one.
  2. **Spans are a strict stack.**  ``span()`` is a context manager; per
     thread, exits happen in reverse entry order, so the exported trace
     is always a well-formed forest (children strictly contained in their
     parents — schema-validated in tests).
  3. **Dispatch vs execute.**  jax calls return as soon as the work is
     enqueued.  ``fence(value, name)`` closes the gap: inside an enclosing
     phase span it opens a child span, ``jax.block_until_ready``-s the
     value, and closes it — so the enclosing span's self-time is the jit
     dispatch cost and the child is the device execution tail.

Events are recorded as flat tuples on the hot path and only shaped into
Chrome-trace dicts at export time.
"""
from __future__ import annotations

import json
import os
import pathlib
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

__all__ = ["Tracer", "span", "fence", "instant", "enable", "disable",
           "get_tracer", "set_tracer", "disabled_span_cost_ns"]

# one event = (name, cat, tid, t0_ns, dur_ns, depth, args)
_Event = Tuple[str, str, int, int, int, int, Optional[Dict[str, Any]]]


class _NullSpan:
    """Shared no-op context manager returned when tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """One live span: records entry/exit timestamps on the tracer."""

    __slots__ = ("_tr", "_name", "_cat", "_args", "_t0", "_depth")

    def __init__(self, tr: "Tracer", name: str, cat: str,
                 args: Optional[Dict[str, Any]]):
        self._tr = tr
        self._name = name
        self._cat = cat
        self._args = args

    def __enter__(self):
        stack = self._tr._stack()
        self._depth = len(stack)
        stack.append(self)
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        t1 = time.perf_counter_ns()
        popped = self._tr._stack().pop()
        assert popped is self, "span exits out of order"
        self._tr._events.append(
            (self._name, self._cat, threading.get_ident(),
             self._t0, t1 - self._t0, self._depth, self._args))
        return False


class Tracer:
    """A span recorder; one per process is the norm (module default below).

    Thread-safe in the sense that each thread keeps its own span stack and
    event appends are atomic list ops; exported timestamps share one
    monotonic clock (``time.perf_counter_ns``).
    """

    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self._events: List[_Event] = []
        self._local = threading.local()

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    # ------------------------------------------------------------- record
    def span(self, name: str, cat: str = "fabric", **args):
        """Context manager timing one phase.  No-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, args or None)

    def fence(self, value, name: str = "device_execute",
              cat: str = "device"):
        """Block on ``value`` inside a child span — the device-execute
        tail of the enclosing dispatch span.  When disabled, returns the
        value untouched (no blocking: the untraced pipeline keeps its
        async dispatch)."""
        if not self.enabled:
            return value
        import jax
        with _Span(self, name, cat, None):
            jax.block_until_ready(value)
        return value

    def instant(self, name: str, cat: str = "fabric", **args) -> None:
        """Zero-duration marker event."""
        if not self.enabled:
            return
        t = time.perf_counter_ns()
        self._events.append((name, cat, threading.get_ident(), t, 0,
                             len(self._stack()), args or None))

    # ------------------------------------------------------------- views
    @property
    def events(self) -> List[_Event]:
        return self._events

    def clear(self) -> None:
        self._events = []

    def phase_totals(self, prefix: str = "") -> Dict[str, Dict[str, float]]:
        """Aggregate inclusive time per span name: ``{name: {count,
        total_us}}``.  Inclusive means a parent's total contains its
        children's; names in the taxonomy are distinct per nesting level,
        so per-name sums stay interpretable."""
        out: Dict[str, Dict[str, float]] = {}
        for name, _cat, _tid, _t0, dur, _d, _a in self._events:
            if prefix and not name.startswith(prefix):
                continue
            row = out.setdefault(name, {"count": 0, "total_us": 0.0})
            row["count"] += 1
            row["total_us"] += dur / 1e3
        for row in out.values():
            row["total_us"] = round(row["total_us"], 1)
        return out

    # ------------------------------------------------------------- export
    def to_chrome(self) -> Dict[str, Any]:
        """The trace as a Chrome-trace JSON object: complete ("ph": "X")
        events with microsecond ``ts``/``dur`` on the shared monotonic
        clock, one ``pid``, real thread ids."""
        pid = os.getpid()
        events = []
        for name, cat, tid, t0, dur, _depth, args in self._events:
            ev: Dict[str, Any] = {
                "name": name, "cat": cat, "ph": "X",
                "ts": t0 / 1e3, "dur": dur / 1e3,
                "pid": pid, "tid": tid,
            }
            if args:
                ev["args"] = args
            events.append(ev)
        events.sort(key=lambda e: (e["tid"], e["ts"], -e["dur"]))
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"tracer": "repro.obs.trace"}}

    def export(self, path) -> pathlib.Path:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_chrome(), indent=1))
        return path


# ------------------------------------------------------- module-level default
_tracer = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _tracer


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process tracer (tests, scoped captures); returns the old."""
    global _tracer
    old, _tracer = _tracer, tracer
    return old


def enable() -> Tracer:
    _tracer.enabled = True
    return _tracer


def disable() -> Tracer:
    _tracer.enabled = False
    return _tracer


def span(name: str, cat: str = "fabric", **args):
    """Module-level span on the process tracer — the instrumentation entry
    point the fabric/server/engine call sites use.  Disabled path: one
    global load + one attribute check + a shared no-op ``with``."""
    tr = _tracer
    if not tr.enabled:
        return _NULL_SPAN
    return _Span(tr, name, cat, args or None)


def fence(value, name: str = "device_execute", cat: str = "device"):
    tr = _tracer
    if not tr.enabled:
        return value
    return tr.fence(value, name, cat)


def instant(name: str, cat: str = "fabric", **args) -> None:
    tr = _tracer
    if tr.enabled:
        tr.instant(name, cat, **args)


def disabled_span_cost_ns(iters: int = 20000) -> float:
    """Measured per-call cost of a DISABLED module-level span — the number
    the <1% overhead gate multiplies by spans-per-batch.  Runs with the
    process tracer forced off for the measurement window."""
    tr = _tracer
    was = tr.enabled
    tr.enabled = False
    try:
        t0 = time.perf_counter_ns()
        for _ in range(iters):
            with span("obs.overhead_probe"):
                pass
        return (time.perf_counter_ns() - t0) / iters
    finally:
        tr.enabled = was
