"""gemma3-4b [dense] — 5:1 local:global sliding window, 128k context.
[hf:google/gemma-3-*] 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144.
Runs long_500k: sub-quadratic by the 5:1 local-window pattern."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4, d_head=256,
    d_ff=10240, vocab=262144, rope_theta=1e6, tie_embeddings=True,
    window=1024, global_every=6,
)
