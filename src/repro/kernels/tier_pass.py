"""HALCONE fused miss/write-pass round kernels (ISSUE 8 tentpole, lever 3).

The batched grant pipeline's round bodies (``coherence.fabric.pipeline``)
are built from per-lane decision math that previously ran as two separate
``lease_probe`` launches plus a dozen gather/select XLA ops per round.
The ``[R, M]`` round masks and the prefix-sum LRU/drain schedules are all
static-shaped, so the whole per-lane decision surface fuses into ONE
Pallas grid pass over the request lanes, the way ``kernels.lease_probe``
fused probe+install for the op-scan:

  * ``miss_round`` — the read-side round math: replica probe, shared
    probe, TSU read grant (Algorithm 3 + the 16-bit overflow reinit) and
    BOTH install levels (Algorithms 1/2) in one kernel.  Serves
    ``pipeline.make_miss_pass``; the state scatters (self-invalidation,
    LRU touch/fill, TSU commit) stay outside — they are cross-lane.
  * ``write_grant`` — the write-side TSU math: probe, lexicographic
    victim (min-``(memts, alloc_seq)``, the host ``TSUShard`` dict-order
    rule), ``mm_write`` grant + overflow reinit.  Serves
    ``core.state.tsu_commit_write_batch`` (the write AND fence passes).

Everything is int32 lattice math — no floats — so fusion is bit-exact by
construction; the parity suites pin it to ``HostFabric`` end to end.

Backend selection matches ``lease_probe``: with ``interpret=None`` the
kernels compile natively on TPU/GPU and fall back to interpret mode on
CPU, where Pallas has no native lowering.  Interpret mode traces the
identical kernel body into plain XLA ops, so the passes are bit-identical
across backends.

Layout contract (DESIGN.md §12c): lanes are blocked over a 1-D grid
``(N // bn,)``; every per-lane vector is ``BlockSpec((bn,), lambda i:
(i,))`` and every gathered set-row matrix ``[N, W]`` is ``BlockSpec((bn,
W), lambda i: (i, 0))`` — whole way-rows live in one block, so way
reductions (first-match, victim argmin) never cross block boundaries.
``bn`` shrinks to the largest divisor of ``N``; callers pass pow2-padded
lane counts so ``bn`` stays a pow2 bucket.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.protocol import TS_MAX

_INVALID = -1          # core.state.INVALID (empty way); pinned by tests
_NEG = -2 ** 30


def _first_match(eq, rows):
    """Value of ``rows`` at the FIRST matching way (0 when no match)."""
    first = eq & (jnp.cumsum(eq.astype(jnp.int32), axis=-1) == 1)
    return jnp.sum(jnp.where(first, rows, 0), axis=-1)


def _b(ref):
    return ref[...] != 0


def _miss_round_kernel(rp_tag_ref, rp_rts_ref, sh_tag_ref, sh_rts_ref,
                       sh_wts_ref, ts_tag_ref, ts_mem_ref, cts1_ref,
                       cts2_ref, addr_ref, act_ref, rd_ref,
                       th1_ref, h1_ref, way1_ref, th2_ref, h2_ref, way2_ref,
                       fnd_ref, tway_ref, mwts_ref, mrts_ref, nmem_ref,
                       ovf_ref, nwa_ref, nra_ref, nw1_ref, nr1_ref):
    i32 = jnp.int32
    addr = addr_ref[...]
    act = _b(act_ref)

    # ---- replica probe (first-match way + protocol.valid)
    eq1 = rp_tag_ref[...] == addr[:, None]
    th1 = eq1.any(axis=-1)
    way1 = jnp.argmax(eq1, axis=-1).astype(i32)
    h1 = th1 & (cts1_ref[...] <= _first_match(eq1, rp_rts_ref[...]))
    th1, h1 = th1 & act, h1 & act
    miss = act & ~h1

    # ---- shared probe (only meaningful on a replica miss)
    eq2 = sh_tag_ref[...] == addr[:, None]
    th2 = eq2.any(axis=-1)
    way2 = jnp.argmax(eq2, axis=-1).astype(i32)
    rts2 = _first_match(eq2, sh_rts_ref[...])
    wts2 = _first_match(eq2, sh_wts_ref[...])
    h2 = th2 & (cts2_ref[...] <= rts2)
    th2, h2 = th2 & miss, h2 & miss
    need = miss & ~h2

    # ---- TSU read grant (Algorithm 3 + 16-bit overflow reinit)
    eqt = ts_tag_ref[...] == addr[:, None]
    tht = eqt.any(axis=-1)
    tway = jnp.argmax(eqt, axis=-1).astype(i32)
    memts = jnp.where(tht, _first_match(eqt, ts_mem_ref[...]), 0)
    rd = rd_ref[...]
    mwts = memts                                  # protocol.mm_read
    mrts = memts + rd
    nmem = mrts
    ovf = nmem > TS_MAX
    mwts = jnp.where(ovf, 0, mwts)
    mrts = jnp.where(ovf, rd, mrts)
    nmem = jnp.where(ovf, mrts, nmem)
    fnd = need & tht

    # ---- response chain: install at shared, then at the replica
    nwa = jnp.maximum(cts2_ref[...], mwts)        # protocol.install
    nra = jnp.maximum(nwa + 1, mrts)
    rwts = jnp.where(h2, wts2, nwa)
    rrts = jnp.where(h2, rts2, nra)
    nw1 = jnp.maximum(cts1_ref[...], rwts)
    nr1 = jnp.maximum(nw1 + 1, rrts)

    for ref, v in ((th1_ref, th1), (h1_ref, h1), (th2_ref, th2),
                   (h2_ref, h2), (fnd_ref, fnd), (ovf_ref, fnd & ovf)):
        ref[...] = v.astype(i32)
    for ref, v in ((way1_ref, way1), (way2_ref, way2), (tway_ref, tway),
                   (mwts_ref, mwts), (mrts_ref, mrts), (nmem_ref, nmem),
                   (nwa_ref, nwa), (nra_ref, nra), (nw1_ref, nw1),
                   (nr1_ref, nr1)):
        ref[...] = v


def _write_grant_kernel(ts_tag_ref, ts_mem_ref, ts_seq_ref, addr_ref,
                        wl_ref, th_ref, way_ref, full_ref, wts_ref,
                        rts_ref, nmem_ref, ovf_ref):
    i32 = jnp.int32
    addr = addr_ref[...]
    tags = ts_tag_ref[...]
    mem = ts_mem_ref[...]

    eq = tags == addr[:, None]
    th = eq.any(axis=-1)
    way = jnp.argmax(eq, axis=-1).astype(i32)
    # lexicographic victim: invalid first, else min memts, ties broken by
    # min alloc seq (state.victim_lex — the host dict-order rule)
    invalid = tags == _INVALID
    p = jnp.where(invalid, i32(_NEG), mem)
    pmin = jnp.min(p, axis=-1, keepdims=True)
    s = jnp.where(p == pmin, ts_seq_ref[...], i32(2 ** 30))
    vic = jnp.argmin(s, axis=-1).astype(i32)
    w0 = jnp.where(th, way, vic)
    full = (~invalid).all(axis=-1)

    memts = jnp.where(th, _first_match(eq, mem), 0)
    wl = wl_ref[...]
    wts = memts + 1                               # protocol.mm_write
    rts = memts + wl
    nmem = rts
    ovf = nmem > TS_MAX
    wts = jnp.where(ovf, 0, wts)
    rts = jnp.where(ovf, wl, rts)
    nmem = jnp.where(ovf, rts, nmem)

    th_ref[...] = th.astype(i32)
    way_ref[...] = w0
    full_ref[...] = full.astype(i32)
    wts_ref[...] = wts
    rts_ref[...] = rts
    nmem_ref[...] = nmem
    ovf_ref[...] = ovf.astype(i32)


def _grid(N, bn):
    bn = min(bn, N)
    while N % bn:
        bn -= 1
    return (N // bn,), bn


def _interp(interpret):
    if interpret is None:
        return jax.default_backend() not in ("tpu", "gpu", "cuda", "rocm")
    return interpret


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def miss_round(rp_tag, rp_rts, sh_tag, sh_rts, sh_wts, ts_tag, ts_mem,
               cts1, cts2, addr, act, rd, *, bn=256, interpret=None):
    """Fused read-side round math over gathered set rows.

    rp_tag/rp_rts: [N, W1] live replica-set ways; sh_tag/sh_rts/sh_wts:
    [N, W2] live shared-set ways; ts_tag/ts_mem: [N, C] the TSU shard's
    fully-associative set; cts1/cts2/addr/act/rd: [N] int32 (act is the
    round mask as 0/1; rd the read lease, broadcast).

    Returns 16 int32 [N] vectors — exactly the intermediates of
    ``make_miss_pass``'s round body:
      th1/h1/way1     — replica tag hit (act-masked), valid hit, way
      th2/h2/way2     — shared tag/valid hit (replica-miss-masked), way
      fnd/tway        — TSU entry found (= miss & ~h2 & tag hit), way
      mwts/mrts/nmem  — TSU read grant + new entry clock (raw, unmasked)
      ovf             — grant re-initialized the entry (fnd-masked)
      nwa/nra         — install at the shared tier (protocol.install)
      nw1/nr1         — install at the replica of the response lease
                        (shared hit's lease when h2, else nwa/nra)
    """
    interpret = _interp(interpret)
    N, W1 = rp_tag.shape
    W2 = sh_tag.shape[1]
    C = ts_tag.shape[1]
    grid, bn = _grid(N, bn)
    row = lambda W: pl.BlockSpec((bn, W), lambda i: (i, 0))
    vec = pl.BlockSpec((bn,), lambda i: (i,))
    outs = pl.pallas_call(
        _miss_round_kernel,
        grid=grid,
        in_specs=[row(W1), row(W1), row(W2), row(W2), row(W2), row(C),
                  row(C), vec, vec, vec, vec, vec],
        out_specs=[vec] * 16,
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.int32)] * 16,
        interpret=interpret,
    )(rp_tag, rp_rts, sh_tag, sh_rts, sh_wts, ts_tag, ts_mem, cts1, cts2,
      addr, act, rd)
    b = lambda x: x.astype(bool)
    (th1, h1, way1, th2, h2, way2, fnd, tway, mwts, mrts, nmem, ovf, nwa,
     nra, nw1, nr1) = outs
    return (b(th1), b(h1), way1, b(th2), b(h2), way2, b(fnd), tway, mwts,
            mrts, nmem, b(ovf), nwa, nra, nw1, nr1)


@functools.partial(jax.jit, static_argnames=("bn", "interpret"))
def write_grant(ts_tag, ts_mem, ts_seq, addr, wl, *, bn=256,
                interpret=None):
    """Fused write-side TSU math over gathered shard rows.

    ts_tag/ts_mem/ts_seq: [N, C] the TSU shard's live ways (tag, entry
    clock, allocation sequence); addr/wl: [N] int32 (wl = the effective
    write lease per lane).

    Returns (th, way, full, wts, rts, nmem, ovf), int32/bool [N]:
      th   — tag hit;  way — the hit way, else the lexicographic victim
      full — every live way is allocated (eviction iff ~th & full)
      wts/rts/nmem/ovf — ``mm_write`` grant + overflow reinit (raw;
      inactive-lane masking is the caller's).
    """
    interpret = _interp(interpret)
    N, C = ts_tag.shape
    grid, bn = _grid(N, bn)
    row = pl.BlockSpec((bn, C), lambda i: (i, 0))
    vec = pl.BlockSpec((bn,), lambda i: (i,))
    outs = pl.pallas_call(
        _write_grant_kernel,
        grid=grid,
        in_specs=[row, row, row, vec, vec],
        out_specs=[vec] * 7,
        out_shape=[jax.ShapeDtypeStruct((N,), jnp.int32)] * 7,
        interpret=interpret,
    )(ts_tag, ts_mem, ts_seq, addr, wl)
    th, way, full, wts, rts, nmem, ovf = outs
    return (th.astype(bool), way, full.astype(bool), wts, rts, nmem,
            ovf.astype(bool))
