"""Litmus tests: the paper's Fig.5 walkthroughs, executed step-for-step."""
import numpy as np

from repro.core import simulate, traces, sm_wt_halcone
from repro.core.engine import FENCE, READ, WRITE


def small_cfg(**kw):
    return sm_wt_halcone(n_gpus=2, cus_per_gpu=2, **kw)


def test_fig5a_intra_gpu():
    """CU0/CU1 of GPU0: order I0-1 -> I1-1 -> I0-2 -> I0-3 -> I1-2 -> I1-3."""
    cfg = small_cfg()
    ops, addrs = traces.litmus_intra(cfg)
    r = simulate(cfg, ops, addrs)
    log0 = np.asarray(r["read_log"][0])
    log1 = np.asarray(r["read_log"][1])
    # I0-1: first read of X -> initial version
    assert log0[0] == 0
    # I0-3: CU0 re-reads X *after* CU1's write, but its cts is within the old
    # lease -> L1 hit returns the OLD data ("read in the past", step 27-29)
    assert log0[3] == 0
    # I1-1: first read of Y
    assert log1[1] == 0
    # I1-3: CU1's cts advanced past Y's rts by its own write of X -> coherency
    # miss -> sees CU0's write (steps 30-34)
    assert log1[5] == 1
    st = r["state"]
    # both writers end with cts advanced by their write lease (paper: 8/11
    # with its per-address example leases; 11/11 under uniform RdLease=10)
    assert st.l1_cts[0] == st.l1_cts[1] == 11


def test_fig5b_inter_gpu():
    """CU0 of GPU0 vs CU0 of GPU1: the final read of Y must come from MM and
    observe GPU0's write (inter-GPU coherence with no invalidation traffic)."""
    cfg = small_cfg()
    ops, addrs = traces.litmus_inter(cfg)
    r = simulate(cfg, ops, addrs)
    gpu0 = np.asarray(r["read_log"][0])
    gpu1 = np.asarray(r["read_log"][cfg.cus_per_gpu])
    assert gpu0[0] == 0 and gpu1[1] == 0          # compulsory reads
    assert gpu0[3] == 0                           # read-in-the-past at GPU0
    assert gpu1[5] == 1                           # coherent refetch at GPU1
    # L2->MM traffic: every write goes through (WT), plus the refetch
    assert float(r["counters"]["l2_to_mm"]) >= 4


def test_write_then_fence_then_read_is_coherent():
    """The DRF guarantee: write (GPU0) -> fence -> read (GPU1) sees the write,
    regardless of lease state (wts = memts+1 > any prior rts; protocol.py)."""
    cfg = small_cfg()
    NC = cfg.n_cus
    X = 5
    T = 6
    ops = np.zeros((NC, T), np.int32)
    addrs = np.zeros((NC, T), np.int32)
    # all CUs read X first (everyone caches it)
    ops[:, 0] = READ
    addrs[:, 0] = X
    # GPU0/CU0 writes
    ops[0, 1] = WRITE
    addrs[0, 1] = X
    # kernel boundary
    ops[:, 2] = FENCE
    # everyone re-reads
    ops[:, 3] = READ
    addrs[:, 3] = X
    r = simulate(cfg, ops, addrs)
    log = np.asarray(r["read_log"])
    assert (log[:, 0] == 0).all()
    assert (log[:, 3] == 1).all(), "post-fence read must observe the write"


def test_unsynchronized_read_may_be_stale_but_never_future():
    cfg = small_cfg()
    NC = cfg.n_cus
    ops = np.zeros((NC, 4), np.int32)
    addrs = np.zeros((NC, 4), np.int32)
    ops[0, 0] = READ
    ops[2, 1] = WRITE          # GPU1 writes without sync
    ops[0, 2] = READ
    addrs[:, :] = 7
    r = simulate(cfg, ops, addrs)
    log0 = np.asarray(r["read_log"][0])
    assert log0[0] == 0
    assert log0[2] in (0, 1)   # weak consistency: stale allowed, garbage not


def test_tsu_parallel_access_no_latency_overhead():
    """TSU is off the critical path: HALCONE's read-miss latency equals the
    non-coherent system's (same trace, no sharing)."""
    from repro.core import sm_wt_nc
    cfg_c = small_cfg()
    cfg_n = sm_wt_nc(n_gpus=2, cus_per_gpu=2)
    NC = cfg_c.n_cus
    rng = np.random.default_rng(0)
    T = 64
    ops = np.full((NC, T), READ, np.int32)
    addrs = rng.integers(0, 4096, (NC, T)).astype(np.int32)  # private-ish
    tc = float(simulate(cfg_c, ops, addrs)["cycles"])
    tn = float(simulate(cfg_n, ops, addrs)["cycles"])
    assert tc <= tn * 1.02, (tc, tn)
