"""Benchmark harness: one module per paper table/figure.

Usage:
  PYTHONPATH=src python -m benchmarks.run [--force] [--only fig7,...]
  PYTHONPATH=src python -m benchmarks.run --suite figures [--mini]

``--suite figures`` drives the figure scripts (fig7/8/9 + the Fig-10
per-link traffic decomposition) through the batched sweep engine (one jit
per grid, DESIGN.md §5) and writes one consolidated artifact
``benchmarks/artifacts/figures.json`` (``figures_mini.json`` with
``--mini`` — the CI footprint: 2 configs x 2 benchmarks, small ROUNDS;
mini keeps fig7 + fig10).

The ``fabric`` suite additionally writes the ROOT-LEVEL perf-trajectory
file ``BENCH_fabric.json`` (batched-vs-host serving ops/sec + lease-sweep
wall-clock; DESIGN.md §7) — ``--mini`` shrinks its op counts to the CI
footprint.  The ``replay`` suite writes ``BENCH_serving.json`` (open-loop
offered-load sweep: continuous vs fixed batch formation, p50/p95/p99 +
SLO goodput + the Fig-10 byte decomposition of the replayed traffic;
DESIGN.md §13).

Prints ``name,us_per_call,derived`` CSV rows (benchmarks/common.emit).
"""
import argparse
import json
import sys
import traceback

from benchmarks.common import ART


def run_figures(force: bool, mini: bool) -> None:
    """The figure suite on the batched sweep engine + consolidated JSON."""
    from benchmarks import (fig7_speedup, fig8_scaling, fig9_xtreme,
                            fig10_traffic)

    consolidated = {"mini": mini}
    consolidated["fig7"] = fig7_speedup.main(force=force, mini=mini)
    consolidated["fig10"] = fig10_traffic.main(force=force, mini=mini)
    if not mini:
        consolidated["fig8"] = fig8_scaling.main(force=force)
        consolidated["fig9"] = fig9_xtreme.main(force=force)
    out = ART / ("figures_mini.json" if mini else "figures.json")
    out.write_text(json.dumps(consolidated, indent=1))
    print(f"figures artifact: {out}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--force", action="store_true",
                    help="recompute instead of using cached artifacts")
    ap.add_argument("--only", default="",
                    help="comma-separated subset (fig2,fig7,fig8,fig9,"
                         "fig10,lease,kernels,roofline,fabric,replay)")
    ap.add_argument("--suite", default="", choices=["", "figures"],
                    help="figures: fig7+fig8+fig9 via the batched sweep "
                         "engine, consolidated into one JSON artifact")
    ap.add_argument("--mini", action="store_true",
                    help="CI footprint: --suite figures runs 2 configs x "
                         "2 benchmarks with small ROUNDS; the fabric suite "
                         "shrinks its op counts")
    args = ap.parse_args()

    print("name,us_per_call,derived")
    if args.suite == "figures":
        run_figures(args.force, args.mini)
        return

    only = set(args.only.split(",")) if args.only else None
    import functools

    from benchmarks import (fabric_bench, fig2_rdma_gap, fig7_speedup,
                            fig8_scaling, fig9_xtreme, fig10_traffic,
                            kernel_bench, lease_sensitivity, replay_bench,
                            roofline)
    suites = [
        ("fig2", fig2_rdma_gap.main),
        ("fig7", fig7_speedup.main),
        ("fig8", fig8_scaling.main),
        ("fig9", fig9_xtreme.main),
        ("fig10", functools.partial(fig10_traffic.main, mini=args.mini)),
        ("lease", lease_sensitivity.main),
        ("kernels", kernel_bench.main),
        ("roofline", roofline.main),
        ("fabric", functools.partial(fabric_bench.run, mini=args.mini)),
        ("replay", functools.partial(replay_bench.run, mini=args.mini)),
    ]
    failed = []
    for name, fn in suites:
        if only and name not in only:
            continue
        try:
            fn(force=args.force)
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
