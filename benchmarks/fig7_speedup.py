"""Fig 7(a,b,c): 5 MGPU configs x 11 standard benchmarks — speedups vs
RDMA-WB-NC, plus L2<->MM and L1<->L2 transaction counts.

Paper targets (geomean over benchmarks, 4 GPUs):
  RDMA-WB-C-HMG 1.5x | SM-WB-NC 3.9x | SM-WT-NC 4.6x | SM-WT-C-HALCONE 4.6x
  (HALCONE within ~1% of SM-WT-NC; ~+1% traffic)
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import cached, emit, timed
from repro.core import simulate, traces
from repro.core.sysconfig import (rdma_wb_hmg, rdma_wb_nc, sm_wb_nc,
                                  sm_wt_halcone, sm_wt_nc)

ROUNDS = 2048
GEOM = dict(pcie_lat=1000.0)   # NVLink/PCIe RDMA round trip ~1us @1GHz
CONFIGS = [
    ("RDMA-WB-NC", rdma_wb_nc),
    ("RDMA-WB-C-HMG", rdma_wb_hmg),
    ("SM-WB-NC", sm_wb_nc),
    ("SM-WT-NC", sm_wt_nc),
    ("SM-WT-C-HALCONE", sm_wt_halcone),
]


def h2d_setup_cycles(cfg, touched_blocks: int) -> float:
    """RDMA systems pay explicit host->device copies (the paper's first
    reason shared memory wins, §5.1) — prorated to the simulated slice."""
    if cfg.topology != "rdma":
        return 0.0
    return touched_blocks * 64 / 32.0  # 32 B/cycle PCIe4


def run_all(force: bool = False):
    def compute():
        out = {}
        for bname, bench in traces.STANDARD.items():
            base = sm_wt_halcone(**GEOM)
            ops, addrs = traces.standard_trace(base, bench, ROUNDS)
            touched = len(np.unique(addrs[(ops == 1) | (ops == 2)]))
            out[bname] = {}
            for cname, mk in CONFIGS:
                cfg = mk(**GEOM)
                r, us = timed(simulate, cfg, ops, addrs)
                cyc = float(r["cycles"]) + h2d_setup_cycles(cfg, touched)
                out[bname][cname] = {
                    "cycles": cyc, "us": us,
                    "l1_to_l2": float(r["counters"]["l1_to_l2"]),
                    "l2_to_mm": float(r["counters"]["l2_to_mm"]),
                    "coh_miss_l1": float(r["counters"]["coh_miss_l1"]),
                }
        return out

    return cached("fig7_speedup", compute, force)


def main(force: bool = False):
    data = run_all(force)
    speedups = {c: [] for c, _ in CONFIGS[1:]}
    for bname, per_cfg in data.items():
        base = per_cfg["RDMA-WB-NC"]["cycles"]
        for cname, _ in CONFIGS[1:]:
            s = base / per_cfg[cname]["cycles"]
            speedups[cname].append(s)
            emit(f"fig7a/{bname}/{cname}", per_cfg[cname]["us"],
                 f"speedup={s:.2f}x")
    for cname, ss in speedups.items():
        gm = float(np.exp(np.mean(np.log(ss))))
        emit(f"fig7a/geomean/{cname}", 0.0, f"speedup={gm:.2f}x")
    # HALCONE overhead vs SM-WT-NC (paper: ~1%)
    ovh, tr = [], []
    for bname, per_cfg in data.items():
        ovh.append(per_cfg["SM-WT-C-HALCONE"]["cycles"]
                   / per_cfg["SM-WT-NC"]["cycles"] - 1)
        tr.append(per_cfg["SM-WT-C-HALCONE"]["l1_to_l2"]
                  / max(per_cfg["SM-WT-NC"]["l1_to_l2"], 1) - 1)
    emit("fig7a/halcone_overhead_vs_smwtnc", 0.0,
         f"mean={np.mean(ovh)*100:.2f}%;max={np.max(ovh)*100:.2f}%")
    emit("fig7c/halcone_extra_l1l2_traffic", 0.0,
         f"mean={np.mean(tr)*100:.2f}%")
    # Fig 7b: WB vs WT L2->MM transactions (paper: WB ~22.7% fewer)
    wb = np.mean([data[b]["SM-WB-NC"]["l2_to_mm"]
                  / max(data[b]["SM-WT-NC"]["l2_to_mm"], 1)
                  for b in data])
    emit("fig7b/wb_l2mm_vs_wt", 0.0, f"ratio={wb:.3f}")
    return data


if __name__ == "__main__":
    main()
