"""Fig 7(a,b,c): 5 MGPU configs x 11 standard benchmarks — speedups vs
RDMA-WB-NC, plus L2<->MM and L1<->L2 transaction counts.

Driven by the batched sweep engine (DESIGN.md §5): the whole 5x11 matrix is
produced by ONE jit (``benchmarks.common.sweep`` -> ``core.engine.sweep``),
with the old per-cell sequential loop timed alongside for the wall-clock
comparison.  ``mini=True`` is the CI footprint: 2 configs x 2 benchmarks at
small ROUNDS, same code path.

Paper targets (geomean over benchmarks, 4 GPUs):
  RDMA-WB-C-HMG 1.5x | SM-WB-NC 3.9x | SM-WT-NC 4.6x | SM-WT-C-HALCONE 4.6x
  (HALCONE within ~1% of SM-WT-NC; ~+1% traffic)
"""
from __future__ import annotations

import numpy as np

from benchmarks import common
from benchmarks.common import cached, emit
from repro.core import traces
from repro.core.sysconfig import (rdma_wb_hmg, rdma_wb_nc, sm_wb_nc,
                                  sm_wt_halcone, sm_wt_nc)

ROUNDS = 2048
GEOM = dict(pcie_lat=1000.0)   # NVLink/PCIe RDMA round trip ~1us @1GHz
CONFIGS = [
    ("RDMA-WB-NC", rdma_wb_nc),
    ("RDMA-WB-C-HMG", rdma_wb_hmg),
    ("SM-WB-NC", sm_wb_nc),
    ("SM-WT-NC", sm_wt_nc),
    ("SM-WT-C-HALCONE", sm_wt_halcone),
]
# CI footprint: baseline + HALCONE over one compute- and one memory-bound
# benchmark, short traces — exercises the identical sweep path.
MINI_CONFIGS = (0, 4)
MINI_BENCHES = ["aes", "mm"]
MINI_ROUNDS = 256


def h2d_setup_cycles(cfg, touched_blocks: int) -> float:
    """RDMA systems pay explicit host->device copies (the paper's first
    reason shared memory wins, §5.1) — prorated to the simulated slice."""
    if cfg.topology != "rdma":
        return 0.0
    return touched_blocks * 64 / 32.0  # 32 B/cycle PCIe4


def run_all(force: bool = False, mini: bool = False):
    benches = MINI_BENCHES if mini else list(traces.STANDARD)
    cfg_rows = [CONFIGS[i] for i in MINI_CONFIGS] if mini else CONFIGS
    rounds = MINI_ROUNDS if mini else ROUNDS

    def compute():
        base = sm_wt_halcone(**GEOM)
        named = {b: traces.standard_trace(base, traces.STANDARD[b], rounds)
                 for b in benches}
        out = common.sweep([(n, mk(**GEOM)) for n, mk in cfg_rows], named)
        # fold in the host->device staging cost (host-side, per config row)
        touched = {b: len(np.unique(named[b][1][(named[b][0] == 1)
                                                | (named[b][0] == 2)]))
                   for b in benches}
        for ci, (_, mk) in enumerate(cfg_rows):
            cfg = mk(**GEOM)
            for bi, b in enumerate(out["benchmarks"]):
                h2d = h2d_setup_cycles(cfg, touched[b])
                out["cycles"][ci][bi] += h2d
                if "sequential_cycles" in out:
                    out["sequential_cycles"][ci][bi] += h2d
        return out

    name = "fig7_sweep_mini" if mini else "fig7_sweep"
    return cached(name, compute, force, script=__file__)


def main(force: bool = False, mini: bool = False):
    data = run_all(force, mini)
    cnames, bnames = data["configs"], data["benchmarks"]
    cyc = np.asarray(data["cycles"])                     # [C, B]
    base = cyc[cnames.index("RDMA-WB-NC")]
    geomeans = {}
    for ci, cname in enumerate(cnames):
        if cname == "RDMA-WB-NC":
            continue
        sp = base / cyc[ci]
        for bi, b in enumerate(bnames):
            emit(f"fig7a/{b}/{cname}", 0.0, f"speedup={sp[bi]:.2f}x")
        gm = float(np.exp(np.mean(np.log(sp))))
        geomeans[cname] = gm
        emit(f"fig7a/geomean/{cname}", 0.0, f"speedup={gm:.2f}x")
    # paper's geomean ordering: HALCONE ~ SM-WT-NC > SM-WB-NC > HMG > RDMA
    if not mini:
        order_ok = (abs(geomeans["SM-WT-C-HALCONE"] / geomeans["SM-WT-NC"]
                        - 1) < 0.05
                    and geomeans["SM-WT-NC"] > geomeans["SM-WB-NC"]
                    > geomeans["RDMA-WB-C-HMG"] > 1.0)
        emit("fig7a/ordering", 0.0,
             f"paper_order={'OK' if order_ok else 'VIOLATED'}")
        # HALCONE overhead vs SM-WT-NC (paper: ~1%)
        hc, wt = cyc[cnames.index("SM-WT-C-HALCONE")], \
            cyc[cnames.index("SM-WT-NC")]
        ovh = hc / wt - 1
        l1l2 = np.asarray(data["counters"]["l1_to_l2"])
        tr = l1l2[cnames.index("SM-WT-C-HALCONE")] \
            / np.maximum(l1l2[cnames.index("SM-WT-NC")], 1) - 1
        emit("fig7a/halcone_overhead_vs_smwtnc", 0.0,
             f"mean={np.mean(ovh)*100:.2f}%;max={np.max(ovh)*100:.2f}%")
        emit("fig7c/halcone_extra_l1l2_traffic", 0.0,
             f"mean={np.mean(tr)*100:.2f}%")
        # Fig 7b: WB vs WT L2->MM transactions (paper: WB ~22.7% fewer)
        l2mm = np.asarray(data["counters"]["l2_to_mm"])
        wb = np.mean(l2mm[cnames.index("SM-WB-NC")]
                     / np.maximum(l2mm[cnames.index("SM-WT-NC")], 1))
        emit("fig7b/wb_l2mm_vs_wt", 0.0, f"ratio={wb:.3f}")
    wall = data["wall"]
    emit("fig7/wall_batched_vs_sequential", wall["batched_cold_s"] * 1e6,
         f"batched_cold={wall['batched_cold_s']:.1f}s;"
         f"batched_steady={wall['batched_steady_s']:.1f}s;"
         f"sequential_cold={wall.get('sequential_cold_s', 0):.1f}s;"
         f"sequential_steady={wall.get('sequential_steady_s', 0):.1f}s;"
         f"speedup_cold={wall.get('batched_speedup_cold', 0):.2f}x;"
         f"speedup_steady={wall.get('batched_speedup_steady', 0):.2f}x")
    return data


if __name__ == "__main__":
    main()
