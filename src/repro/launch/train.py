"""Training launcher.

Single-host/CPU:      PYTHONPATH=src python -m repro.launch.train \
                          --arch smollm-360m --smoke --steps 20
Production meshes use the same Trainer with make_production_mesh(); on real
TPU pods run one process per host (jax.distributed.initialize) — the code
paths are identical, only the mesh differs.
"""
import argparse

from repro import configs as cfgs
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.runtime.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m", choices=list(cfgs.ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--mesh", default="host",
                    choices=["host", "single", "multi"])
    args = ap.parse_args()

    cfg = cfgs.SMOKE[args.arch] if args.smoke else cfgs.get(args.arch)
    mesh = (make_host_mesh() if args.mesh == "host" else
            make_production_mesh(multi_pod=(args.mesh == "multi")))
    data = SyntheticLM(cfg, DataConfig(global_batch=args.batch,
                                       seq_len=args.seq))
    trainer = Trainer(cfg, mesh,
                      tcfg=TrainerConfig(total_steps=args.steps,
                                         ckpt_period=max(args.steps // 5, 1),
                                         ckpt_dir=args.ckpt_dir),
                      data=data)
    out = trainer.run()
    print(f"done: steps={out['final_step']} "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f} "
          f"events={out['events']}")


if __name__ == "__main__":
    main()
