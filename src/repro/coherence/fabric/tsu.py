"""The sharded TSU service: the single lease authority for the whole repo.

The paper places one timestamp storage unit per HBM stack; the fabric mirrors
that as N ``TSUShard``s behind a stable key-hash (``TSUFabric.shard_of``).
Each shard is the MM+TSU pair for its keys: it holds the authoritative value
and version (MM) next to the 16-bit logical clock ``memts`` (TSU), and it is
the ONLY place host code may execute the paper's Algorithms 1-5 — every
timestamp decision here is a call into ``repro.core.protocol``; nothing is
re-derived.

Overflow (paper §: 16-bit counters): when a grant would push ``memts`` past
``protocol.TS_MAX`` the entry re-initializes to 0 and the grant is recomputed
from the fresh clock — write-through means MM always holds the data, so the
only cost is the one extra MM access the paper cites.  This matches the
engine's in-round reinit (wts=0, rts=lease, memts'=rts).
"""
from __future__ import annotations

import dataclasses
import hashlib
import weakref
from typing import Any, Dict, List, NamedTuple, Optional

from repro.core import protocol
from repro.core.state import BLOCK_BYTES
from repro.coherence.fabric.stats import FabricStats


@dataclasses.dataclass(frozen=True)
class FabricConfig:
    n_shards: int = 4
    rd_lease: int = 8
    wr_lease: int = 4
    tsu_capacity: Optional[int] = None   # per-shard entry cap (None = unbounded)
    shared_sets: int = 64                # node-shared tier geometry
    shared_ways: int = 4
    replica_sets: int = 32               # replica tier geometry
    replica_ways: int = 2
    max_in_flight: int = 8               # write-queue bound

    def __post_init__(self):
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.rd_lease < 1 or self.wr_lease < 1:
            raise ValueError("rd_lease/wr_lease must be >= 1, got "
                             f"{self.rd_lease}/{self.wr_lease}")


class LeaseGrant(NamedTuple):
    """A TSU response: the block plus its [wts, rts] lease."""
    value: Any
    version: int
    wts: int
    rts: int
    shard: int


@dataclasses.dataclass
class _Entry:
    """One MM block + its TSU row (value/version = MM, memts = TSU)."""
    value: Any = None
    version: int = 0
    memts: int = 0


def stable_hash(key) -> int:
    """Process-independent key hash (python's hash() is salted per run)."""
    if not isinstance(key, bytes):
        key = str(key).encode()
    return int.from_bytes(hashlib.blake2b(key, digest_size=8).digest(), "big")


class TSUShard:
    """One per-HBM-stack TSU: grants leases for the keys hashed to it."""

    def __init__(self, shard_id: int, cfg: FabricConfig, stats: FabricStats):
        self.shard_id = shard_id
        self.cfg = cfg
        self.stats = stats
        self.entries: Dict[Any, _Entry] = {}

    # ------------------------------------------------------------- grants
    def mm_read(self, key) -> Optional[LeaseGrant]:
        e = self.entries.get(key)
        if e is None:
            return None
        lease, new_memts = protocol.mm_read(e.memts, self.cfg.rd_lease)
        wts, rts, e.memts = self._reinit(lease, new_memts, self.cfg.rd_lease)
        return LeaseGrant(e.value, e.version, wts, rts, self.shard_id)

    def mm_write(self, key, value, wr_lease: Optional[int] = None) -> LeaseGrant:
        wl = self.cfg.wr_lease if wr_lease is None else wr_lease
        e = self.entries.get(key)
        if e is None:
            e = self._allocate(key)
        lease, new_memts = protocol.mm_write(e.memts, wl)
        wts, rts, e.memts = self._reinit(lease, new_memts, wl)
        e.value = value
        e.version += 1
        return LeaseGrant(e.value, e.version, wts, rts, self.shard_id)

    # ------------------------------------------------------------ helpers
    def _reinit(self, lease: protocol.Lease, new_memts: int, lease_len: int):
        """16-bit overflow reinit, same grant the engine computes: the clock
        restarts at 0 and the request is re-served as a first access."""
        if int(protocol.overflow_reinit(new_memts)) != new_memts:
            self.stats.bump("overflow_reinits")
            lease, new_memts = protocol.mm_read(0, lease_len)
        return int(lease.wts), int(lease.rts), int(new_memts)

    def _allocate(self, key) -> _Entry:
        cap = self.cfg.tsu_capacity
        if cap is not None and len(self.entries) >= cap:
            # victim-way: evict the min-memts row (the engine's TSU victim);
            # its next requester simply re-initializes from memts=0.
            victim = min(self.entries, key=lambda k: self.entries[k].memts)
            del self.entries[victim]
            self.stats.bump("tsu_evictions")
        e = _Entry()
        self.entries[key] = e
        return e


class TSUFabric:
    """Key-hash router over the shards — the one front door for leases.

    ``home_shard`` on read/write identifies the caller's local stack; an
    access routed to any other shard is a cross-switch hop and is counted as
    ``pcie_blocks``, same as the simulator counts remote traffic.
    """

    def __init__(self, cfg: FabricConfig = FabricConfig()):
        self.cfg = cfg
        self.stats = FabricStats()
        self.shards: List[TSUShard] = [
            TSUShard(i, cfg, self.stats) for i in range(cfg.n_shards)]
        # weakly-held registries: a Server/cache torn down elsewhere must not
        # be kept alive (or flushed) by the fabric forever
        self._caches: list = []          # weakrefs to client clocks (barrier)
        self._queues: list = []          # weakrefs to write queues

    # ------------------------------------------------------------ routing
    def shard_of(self, key) -> int:
        return stable_hash(key) % self.cfg.n_shards

    # ------------------------------------------------------------- access
    def read(self, key, home_shard: Optional[int] = None) -> Optional[LeaseGrant]:
        s = self.shard_of(key)
        self.stats.bump("l2_to_mm")
        self.stats.bump("bytes_l2_mm", BLOCK_BYTES)
        if home_shard is not None and s != home_shard:
            self.stats.bump("pcie_blocks")
            self.stats.bump("bytes_inter_gpu", BLOCK_BYTES)
        return self.shards[s].mm_read(key)

    def write(self, key, value, *, wr_lease: Optional[int] = None,
              home_shard: Optional[int] = None) -> LeaseGrant:
        s = self.shard_of(key)
        self.stats.bump("l2_to_mm")
        self.stats.bump("bytes_l2_mm", BLOCK_BYTES)
        self.stats.bump("write_throughs")
        if home_shard is not None and s != home_shard:
            self.stats.bump("pcie_blocks")
            self.stats.bump("bytes_inter_gpu", BLOCK_BYTES)
        return self.shards[s].mm_write(key, value, wr_lease)

    def memts(self, key) -> int:
        e = self.shards[self.shard_of(key)].entries.get(key)
        return 0 if e is None else e.memts

    def entries(self) -> Dict[Any, _Entry]:
        """Merged live view of every shard's MM+TSU rows."""
        out: Dict[Any, _Entry] = {}
        for sh in self.shards:
            out.update(sh.entries)
        return out

    # ------------------------------------------------------------ barrier
    def attach(self, cache) -> None:
        self._caches.append(weakref.ref(cache))

    def attach_queue(self, queue) -> None:
        self._queues.append(weakref.ref(queue))

    @staticmethod
    def _live(refs: list) -> list:
        alive = [(r, o) for r in refs if (o := r()) is not None]
        refs[:] = [r for r, _ in alive]          # prune dead registrations
        return [o for _, o in alive]

    def barrier(self) -> int:
        """Kernel-boundary fence (engine op 3): drain every in-flight write,
        then jump every attached clock to the global maximum cts."""
        for q in self._live(self._queues):
            q.flush()
        self.stats.bump("fences")
        caches = self._live(self._caches)
        gmax = max((c.cts for c in caches), default=0)
        for c in caches:
            c.cts = max(c.cts, gmax)
        return gmax
