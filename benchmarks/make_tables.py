"""Generate the EXPERIMENTS.md markdown tables from dry-run artifacts."""
import json
import pathlib
import sys

ART = pathlib.Path(__file__).resolve().parent / "artifacts" / "dryrun"


def fmt(x):
    if x == 0:
        return "0"
    if x < 1e-3 or x >= 1e4:
        return f"{x:.2e}"
    return f"{x:.3g}"


def table(variant: str):
    d = ART / variant
    rows = []
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        rl = r["roofline"]
        dom = max(rl["t_compute_s"], rl["t_memory_s"], rl["t_collective_s"])
        frac = (rl["t_compute_s"] / dom) if dom else 0.0
        rows.append(
            f"| {r['arch']} | {r['shape']} | {fmt(rl['t_compute_s'])} | "
            f"{fmt(rl['t_memory_s'])} | {fmt(rl['t_collective_s'])} | "
            f"{rl['bottleneck'][:4]} | {rl['useful_flop_ratio']:.2f} | "
            f"{frac:.2f} | {r['compile_s']:.0f}s |")
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
           "useful | roofline-frac | compile |\n"
           "|---|---|---|---|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


def memory_table(variant: str):
    d = ART / variant
    rows = []
    for p in sorted(d.glob("*.json")):
        r = json.loads(p.read_text())
        m = r.get("memory_analysis", {})
        arg = m.get("argument_size_in_bytes", 0) / 1e9
        tmp = m.get("temp_size_in_bytes", 0) / 1e9
        peak = m.get("peak_memory_in_bytes", 0) / 1e9
        rows.append(f"| {r['arch']} | {r['shape']} | {arg:.2f} | {tmp:.2f} | "
                    f"{peak:.2f} |")
    hdr = ("| arch | shape | args GB/dev | temps GB/dev | peak GB/dev |\n"
           "|---|---|---|---|---|")
    return hdr + "\n" + "\n".join(rows)


if __name__ == "__main__":
    variant = sys.argv[1] if len(sys.argv) > 1 else "single"
    which = sys.argv[2] if len(sys.argv) > 2 else "roofline"
    print(table(variant) if which == "roofline" else memory_table(variant))
