"""Static analyzer for post-optimization HLO text.

Why: ``compiled.cost_analysis()`` visits each while-loop body ONCE, so any
model whose layers run under ``lax.scan`` under-reports FLOPs / bytes /
collective traffic by the trip count.  This walks the call graph with loop
multipliers instead:

  flops      — 2*M*N*K per dot (batch dims included), x loop trips
  hbm bytes  — operand+result bytes at fusion/op boundaries (XLA's fusion
               boundary is the HBM traffic boundary), x loop trips
  wire bytes — per-collective ring-model bytes (roofline.py), x loop trips

Trip counts: scan lowers to while(tuple(...)); the condition compares a
get-tuple-element (counter) against another (bound); we trace the bound back
to its constant through the while's init tuple.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

from repro.launch.roofline import _DTYPE_BYTES, _group_size, _wire_bytes

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%?[\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?(%[\w.\-]+)\s*=\s*(\([^()]*\)|[\w\[\],{}\s/*]+?)\s+([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%[\w.\-]+")
_CONST_RE = re.compile(r"constant\((-?\d+)\)")
_GTE_IDX = re.compile(r"index=(\d+)")

_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "add-dependency", "while",
               "conditional", "call", "partition-id", "replica-id",
               "get-dimension-size", "domain", "opt-barrier"}
_COLLECTIVES = {"all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast",
                "all-gather-start", "all-reduce-start",
                "collective-permute-start", "all-to-all-start"}


def _shape_elems(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shape_elems(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str                 # operands + attrs tail


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    by_name: Dict[str, Op]


def parse_module(text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    entry = ""
    cur: Optional[Computation] = None
    for line in text.splitlines():
        hdr = _COMP_HDR.match(line.strip()) if "{" in line else None
        if hdr and ("->" in line):
            nm = hdr.group(1).lstrip("%")
            cur = Computation(nm, [], {})
            comps[nm] = cur
            if line.strip().startswith("ENTRY"):
                entry = nm
            continue
        if line.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _DEF_RE.match(line)
        if m:
            op = Op(m.group(1), m.group(2).strip(), m.group(3), m.group(4))
            cur.ops.append(op)
            cur.by_name[op.name] = op
    return comps, entry


def _dot_flops(op: Op, comp: Computation) -> float:
    result_n = 1
    for _, dims in _shape_elems(op.type_str):
        for d in dims:
            result_n *= d
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    if not m:
        return 2.0 * result_n
    cdims = [int(x) for x in m.group(1).split(",") if x]
    opnds = _OPERAND_RE.findall(op.rest.split(", lhs_contracting")[0])
    k = 1
    if opnds:
        lhs = comp.by_name.get(opnds[0])
        if lhs is not None:
            els = _shape_elems(lhs.type_str)
            if els:
                dims = els[0][1]
                for c in cdims:
                    if c < len(dims):
                        k *= dims[c]
    return 2.0 * result_n * k


def _trip_count(comps, comp: Computation, op: Op) -> int:
    """Trace scan trip count: cond ROOT compare(gte_i, gte_j) -> init tuple."""
    mc = re.search(r"condition=(%[\w.\-]+)", op.rest)
    if not mc:
        return 1
    cond = comps.get(mc.group(1).lstrip("%"))
    if cond is None:
        return 1
    root = cond.ops[-1] if cond.ops else None
    for o in cond.ops:
        if o.opcode == "compare" and "direction=LT" in o.rest:
            root = o
            break
    if root is None or root.opcode != "compare":
        # fallback: largest constant in the condition computation
        consts = [int(c) for o in cond.ops for c in _CONST_RE.findall(
            o.opcode + "(" + o.rest)]
        return max(consts) if consts else 1
    sides = _OPERAND_RE.findall(root.rest)[:2]
    idxs = []
    for s in sides:
        d = cond.by_name.get(s)
        if d is not None and d.opcode == "get-tuple-element":
            mi = _GTE_IDX.search(d.rest)
            if mi:
                idxs.append(int(mi.group(1)))
        elif d is not None and d.opcode == "constant":
            mi = _CONST_RE.search("constant(" + d.rest)
            if mi:
                return max(1, int(mi.group(1)))
    if not idxs:
        return 1
    # find the while's init tuple in the parent computation
    parent = None
    for c in comps.values():
        if op.name in c.by_name and c.by_name[op.name] is op:
            parent = c
            break
    if parent is None:
        return 1
    init_ref = _OPERAND_RE.findall(op.rest)
    init = parent.by_name.get(init_ref[0]) if init_ref else None
    if init is None or init.opcode != "tuple":
        return 1
    elems = _OPERAND_RE.findall(init.rest)
    vals = []
    for j in idxs:
        if j < len(elems):
            d = parent.by_name.get(elems[j])
            if d is not None and d.opcode == "constant":
                mi = _CONST_RE.search("constant(" + d.rest)
                if mi:
                    vals.append(int(mi.group(1)))
    return max([v for v in vals if v > 0], default=1)


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    coll_per_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_per_group: Dict[int, float] = dataclasses.field(default_factory=dict)
    n_collectives: int = 0
    trips: Dict[str, int] = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.hbm_bytes += other.hbm_bytes * mult
        self.wire_bytes += other.wire_bytes * mult
        for k, v in other.coll_per_kind.items():
            self.coll_per_kind[k] = self.coll_per_kind.get(k, 0.0) + v * mult
        for k, v in other.coll_per_group.items():
            self.coll_per_group[k] = self.coll_per_group.get(k, 0.0) + v * mult
        self.n_collectives += int(other.n_collectives * mult)
        self.trips.update(other.trips)


def _called(op: Op, attr: str) -> Optional[str]:
    m = re.search(attr + r"=(%[\w.\-]+)", op.rest)
    return m.group(1).lstrip("%") if m else None


def analyze(text: str, n_devices: int) -> Cost:
    comps, entry = parse_module(text)
    memo: Dict[Tuple[str, bool], Cost] = {}

    def comp_cost(name: str, fusion_internal: bool) -> Cost:
        key = (name, fusion_internal)
        if key in memo:
            return memo[key]
        memo[key] = Cost()                       # break cycles defensively
        comp = comps.get(name)
        if comp is None:
            return memo[key]
        c = Cost()
        for op in comp.ops:
            if op.opcode == "dot" or op.opcode.endswith("convolution"):
                c.flops += _dot_flops(op, comp)
                if not fusion_internal:
                    c.hbm_bytes += _op_bytes(op, comp)
            elif op.opcode == "fusion":
                callee = _called(op, "calls")
                if callee:
                    c.add(comp_cost(callee, True))
                if not fusion_internal:
                    c.hbm_bytes += _op_bytes(op, comp)
            elif op.opcode == "while":
                body = _called(op, "body")
                cond = _called(op, "condition")
                trips = _trip_count(comps, comp, op)
                c.trips[op.name] = trips
                if body:
                    c.add(comp_cost(body, fusion_internal), trips)
                if cond:
                    c.add(comp_cost(cond, fusion_internal), trips)
            elif op.opcode == "conditional":
                for br in re.findall(r"branch_computations=\{([^}]*)\}",
                                     op.rest):
                    for nm in _OPERAND_RE.findall(br):
                        c.add(comp_cost(nm.lstrip("%"), fusion_internal))
                tc = _called(op, "true_computation")
                fc = _called(op, "false_computation")
                for nm in (tc, fc):
                    if nm:
                        c.add(comp_cost(nm, fusion_internal))
            elif op.opcode == "call":
                callee = _called(op, "to_apply")
                if callee:
                    c.add(comp_cost(callee, fusion_internal))
            elif op.opcode.replace("-start", "").replace("-done", "") in (
                    "all-gather", "all-reduce", "reduce-scatter",
                    "all-to-all", "collective-permute",
                    "collective-broadcast"):
                if op.opcode.endswith("-done"):
                    continue
                kind = op.opcode.replace("-start", "")
                rb = _type_bytes(op.type_str)
                n = _group_size(op.opcode + "(" + op.rest, n_devices)
                wb = _wire_bytes(kind, rb, n)
                c.wire_bytes += wb
                c.coll_per_kind[kind] = c.coll_per_kind.get(kind, 0.0) + wb
                c.coll_per_group[n] = c.coll_per_group.get(n, 0.0) + wb
                c.n_collectives += 1
                if not fusion_internal:
                    c.hbm_bytes += _op_bytes(op, comp)
            else:
                if not fusion_internal and op.opcode not in _SKIP_BYTES:
                    c.hbm_bytes += _op_bytes(op, comp)
        memo[key] = c
        return c

    def _op_bytes(op: Op, comp: Computation) -> float:
        total = float(_type_bytes(op.type_str))
        head = op.rest.split("), ")[0]
        for ref in _OPERAND_RE.findall(head):
            d = comp.by_name.get(ref)
            if d is not None and d.opcode not in ("constant",):
                total += _type_bytes(d.type_str)
        return total

    return comp_cost(entry, False)
