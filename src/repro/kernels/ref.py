"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import protocol


def attention_ref(q, k, v, *, causal=True, window=0, kv_len=None):
    """q: [B,Sq,Hq,D]; k,v: [B,Sk,Hkv,D] — plain softmax attention."""
    B, Sq, Hq, D = q.shape
    Sk, Hkv = k.shape[1], k.shape[2]
    qpk = Hq // Hkv
    qr = q.reshape(B, Sq, Hkv, qpk, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(jnp.float32)) * D ** -0.5
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.zeros((Sq, Sk), jnp.float32)
    if causal:
        mask = jnp.where(kpos > qpos, -1e30, mask)
    if window:
        mask = jnp.where(qpos - kpos >= window, -1e30, mask)
    if kv_len is not None:
        mask = jnp.where(kpos >= kv_len, -1e30, mask)
    p = jax.nn.softmax(s + mask, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Sq, Hq, D).astype(q.dtype)


def ssd_chunk_ref(x, dt, A, Bc, Cc):
    """Intra-chunk SSD reference for ONE chunk.

    x: [Q,P]; dt: [Q]; A: scalar; Bc, Cc: [Q,N].
    Returns (y_intra [Q,P], chunk_state [N,P], cum [Q])."""
    dA = dt * A
    cum = jnp.cumsum(dA)
    li = cum[:, None] - cum[None, :]
    L = jnp.exp(jnp.where(jnp.tril(jnp.ones_like(li, bool)), li, -jnp.inf))
    cb = Cc.astype(jnp.float32) @ Bc.astype(jnp.float32).T      # [Q,Q]
    scores = cb * L * dt[None, :]
    y = scores @ x.astype(jnp.float32)
    decay_out = jnp.exp(cum[-1] - cum)
    state = (Bc.astype(jnp.float32) * (dt * decay_out)[:, None]).T \
        @ x.astype(jnp.float32)                                  # [N,P]
    return y.astype(x.dtype), state, cum


def rmsnorm_ref(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
    return (y * (1.0 + w.astype(jnp.float32))).astype(x.dtype)


def lease_probe_ref(tag_rows, rts_rows, cts, addr, mwts, mrts):
    """HALCONE probe+install math (engine hot loop) over gathered set rows.

    tag_rows/rts_rows: [N,W]; cts/addr/mwts/mrts: [N].
    Returns (tag_hit, hit, way, row_rts, new_wts, new_rts, new_cts) —
    the same seven outputs as kernels.lease_probe, derived exclusively
    from core.protocol so the kernel's math is pinned to Algorithms 1-5."""
    eq = tag_rows == addr[:, None]
    tag_hit = eq.any(-1)
    way = jnp.argmax(eq, -1).astype(jnp.int32)
    rts = jnp.take_along_axis(rts_rows, way[:, None], 1)[:, 0]
    row_rts = jnp.where(tag_hit, rts, 0)
    hit = tag_hit & protocol.valid(cts, row_rts)
    lease = protocol.install(cts, mwts, mrts)
    new_cts = protocol.cts_after_write(cts, lease.wts)
    return tag_hit, hit, way, row_rts, lease.wts, lease.rts, new_cts


def _first_match_ref(eq, rows):
    first = eq & (jnp.cumsum(eq.astype(jnp.int32), -1) == 1)
    return jnp.sum(jnp.where(first, rows, 0), -1)


def _tsu_grant_ref(memts, is_write, lease_v):
    """Algorithm 3 + the 16-bit overflow reinit (protocol.mm_*), one
    side at a time (``lease_v`` = rd or wr lease per lane)."""
    if is_write:
        lease, new_memts = protocol.mm_write(memts, lease_v)
    else:
        lease, new_memts = protocol.mm_read(memts, lease_v)
    ovf = new_memts > protocol.TS_MAX
    wts = jnp.where(ovf, 0, lease.wts)
    rts = jnp.where(ovf, lease_v, lease.rts)
    return wts, rts, jnp.where(ovf, rts, new_memts), ovf


def miss_round_ref(rp_tag, rp_rts, sh_tag, sh_rts, sh_wts, ts_tag, ts_mem,
                   cts1, cts2, addr, act, rd):
    """Read-side round math (kernels.tier_pass.miss_round), derived
    exclusively from core.protocol: replica probe, shared probe, TSU read
    grant, and both install levels — the 16 per-lane intermediates of
    ``pipeline.make_miss_pass``'s round body."""
    act = act != 0
    eq1 = rp_tag == addr[:, None]
    th1 = eq1.any(-1)
    way1 = jnp.argmax(eq1, -1).astype(jnp.int32)
    h1 = th1 & protocol.valid(cts1, _first_match_ref(eq1, rp_rts))
    th1, h1 = th1 & act, h1 & act
    miss = act & ~h1

    eq2 = sh_tag == addr[:, None]
    th2 = eq2.any(-1)
    way2 = jnp.argmax(eq2, -1).astype(jnp.int32)
    rts2 = _first_match_ref(eq2, sh_rts)
    wts2 = _first_match_ref(eq2, sh_wts)
    h2 = th2 & protocol.valid(cts2, rts2)
    th2, h2 = th2 & miss, h2 & miss
    need = miss & ~h2

    eqt = ts_tag == addr[:, None]
    tht = eqt.any(-1)
    tway = jnp.argmax(eqt, -1).astype(jnp.int32)
    memts = jnp.where(tht, _first_match_ref(eqt, ts_mem), 0)
    mwts, mrts, nmem, ovf = _tsu_grant_ref(memts, False, rd)
    fnd = need & tht

    leaseA = protocol.install(cts2, mwts, mrts)
    rwts = jnp.where(h2, wts2, leaseA.wts)
    rrts = jnp.where(h2, rts2, leaseA.rts)
    lease1 = protocol.install(cts1, rwts, rrts)
    return (th1, h1, way1, th2, h2, way2, fnd, tway, mwts, mrts, nmem,
            fnd & ovf, leaseA.wts, leaseA.rts, lease1.wts, lease1.rts)


def write_grant_ref(ts_tag, ts_mem, ts_seq, addr, wl, invalid=-1):
    """Write-side TSU math (kernels.tier_pass.write_grant): probe,
    lexicographic victim (min-(memts, alloc_seq); the host dict-order
    rule) and the ``mm_write`` grant + overflow reinit."""
    eq = ts_tag == addr[:, None]
    th = eq.any(-1)
    way = jnp.argmax(eq, -1).astype(jnp.int32)
    inval = ts_tag == invalid
    p = jnp.where(inval, jnp.int32(-2 ** 30), ts_mem)
    pmin = jnp.min(p, -1, keepdims=True)
    s = jnp.where(p == pmin, ts_seq, jnp.int32(2 ** 30))
    vic = jnp.argmin(s, -1).astype(jnp.int32)
    w0 = jnp.where(th, way, vic)
    full = (~inval).all(-1)
    memts = jnp.where(th, _first_match_ref(eq, ts_mem), 0)
    wts, rts, nmem, ovf = _tsu_grant_ref(memts, True, wl)
    return th, w0, full, wts, rts, nmem, ovf
